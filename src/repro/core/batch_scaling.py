"""Algorithm 2 — Batch Size Scaling with Best Sharing Benefit.

Given a running job and a new job that would share the running job's GPUs,
sweep the new job's per-GPU sub-batch b over {B, ceil(B/2), ..., 1}
(gradient accumulation supplies s = ceil(B/b) micro-steps — the final
micro-batch absorbs the remainder when b does not divide B, so the
*effective* batch, and hence convergence, is unchanged for every
candidate), check memory feasibility of the pair, apply Theorem 1 per
candidate, and return the best (SF, b, t_bar).

:mod:`repro.core.pair_batch` is the NumPy-vectorized form of the same
sweep over *all* donors at once; this scalar version is kept as the
equivalence reference (``tests/test_pair_batch.py``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from .interference import InterferenceModel
from .job import Job
from .pair import PairDecision, PairJob, best_pair_schedule, pair_timeline


@dataclass(frozen=True)
class SharingConfig:
    share: bool                 # SF
    sub_batch: int              # b (new job's per-GPU sub-batch)
    accum_steps: int            # s = B / b
    avg_jct: float              # t_bar
    decision: Optional[PairDecision]
    xi_new: float = 1.0
    xi_run: float = 1.0


_CANDIDATES_MEMO: dict = {}


def candidate_sub_batches(batch: int) -> list[int]:
    """B, B/2, ..., 1 (powers-of-two steps, as in Algorithm 2).
    Memoized per batch size (a trace has few distinct batches but every
    arriving job asks); treat the result as read-only."""
    out = _CANDIDATES_MEMO.get(batch)
    if out is not None:
        return out
    out = []
    b = batch
    while b >= 1:
        out.append(int(b))
        if b == 1:
            break
        b = math.ceil(b / 2)
    _CANDIDATES_MEMO[batch] = out
    return out


def best_sharing_config(
    running: Job,
    new: Job,
    interference: InterferenceModel,
    gpu_capacity_bytes: float,
    rem_run: Optional[float] = None,
) -> SharingConfig:
    """Algorithm 2. ``running`` keeps its current sub-batch (the paper does
    not re-tune the running job); only the new job's b is swept.
    ``rem_run`` overrides the donor's remaining iterations (schedulers
    pass the engine's virtual read, ``Simulator.remaining_at``)."""
    run_mem = running.perf.mem_bytes(running.sub_batch)
    t_run = running.solo_t_iter
    if rem_run is None:
        rem_run = running.remaining_iters
    # xi is independent of the candidate sub-batch under a global override
    # or a two-way pair-table hit; only the structural fallback needs the
    # per-candidate timing/memory arguments.
    fixed_xi = interference.pair_fixed(running.model, new.model)
    best: Optional[SharingConfig] = None

    for b in candidate_sub_batches(new.batch):
        s = max(1, math.ceil(new.batch / b))
        new_mem = new.perf.mem_bytes(b)
        if new_mem + run_mem > gpu_capacity_bytes:
            continue  # pair does not fit device memory at this sub-batch
        t_new = new.t_iter_sub(b)
        if fixed_xi is not None:
            xi_run, xi_new = fixed_xi
        else:
            mem_frac = (run_mem + new_mem) / gpu_capacity_bytes
            xi_run = interference.xi(
                running.model, new.model,
                t_me=t_run, t_other=t_new, mem_frac=mem_frac)
            xi_new = interference.xi(
                new.model, running.model,
                t_me=t_new, t_other=t_run, mem_frac=mem_frac)
        a = PairJob(t_iter=t_run, iters=rem_run, xi=xi_run)
        bb = PairJob(t_iter=t_new, iters=new.iters, xi=xi_new)
        dec = best_pair_schedule(a, bb)
        cfg = SharingConfig(
            share=dec.share, sub_batch=b, accum_steps=s,
            avg_jct=dec.avg_jct, decision=dec, xi_new=xi_new, xi_run=xi_run,
        )
        if best is None or cfg.avg_jct < best.avg_jct:
            best = cfg
        if fixed_xi is not None:
            # With b-independent xi the pair-average JCT is monotone
            # nondecreasing as the sub-batch shrinks (t_iter(B, s) grows
            # with s and both Theorem-1 endpoints grow with the new
            # job's iteration time), so the first (largest) feasible
            # sub-batch is optimal — same winner as the full sweep.
            break

    if best is None:
        # No sub-batch fits next to the running job -> cannot share.
        return SharingConfig(False, new.batch, 1, float("inf"), None)
    return best


@dataclass(frozen=True)
class DonorScaledConfig:
    """Result of the donor-rescaling extension: like
    :class:`SharingConfig` plus the DONOR's new sub-batch."""

    share: bool
    donor_sub_batch: int        # running job's new b (its B is unchanged)
    sub_batch: int              # new job's b
    accum_steps: int
    avg_jct: float
    xi_run: float = 1.0
    xi_new: float = 1.0


def best_sharing_config_donor_scaled(
    running: Job,
    new: Job,
    interference: InterferenceModel,
    gpu_capacity_bytes: float,
    rem_run: Optional[float] = None,
) -> DonorScaledConfig:
    """Algorithm-2 extension (DESIGN.md §13): when no sub-batch of the
    new job fits beside the donor's *current* footprint, sweep the
    DONOR's sub-batch down too — the donor accepts extra gradient
    accumulation (slower iterations, unchanged effective batch) to make
    memory room for the sharer. This is a mid-run (τ, sub-batch)
    reconfiguration of the running job: the scheduler applies it via
    ``Simulator.reconfigure_job`` at the sharing time point, and the
    physical executor re-fuses the group program with the new
    accumulation while carrying the donor's params/opt state through.

    The sequential baseline prices the donor at its CURRENT sub-batch
    (declining to share leaves it untouched), so the donor's slowdown is
    charged against the sharing benefit — a pair only shares when the
    benefit survives the reconfiguration cost."""
    if rem_run is None:
        rem_run = running.remaining_iters
    t_run_cur = running.solo_t_iter
    fixed_xi = interference.pair_fixed(running.model, new.model)
    best: Optional[DonorScaledConfig] = None

    for b_run in candidate_sub_batches(running.batch):
        if b_run >= running.sub_batch:
            continue   # only shrinking the donor can unlock memory
        run_mem = running.perf.mem_bytes(b_run)
        t_run = running.t_iter_sub(b_run)
        for b_new in candidate_sub_batches(new.batch):
            new_mem = new.perf.mem_bytes(b_new)
            if run_mem + new_mem > gpu_capacity_bytes:
                continue
            t_new = new.t_iter_sub(b_new)
            if fixed_xi is not None:
                xi_run, xi_new = fixed_xi
            else:
                mem_frac = (run_mem + new_mem) / gpu_capacity_bytes
                xi_run = interference.xi(
                    running.model, new.model,
                    t_me=t_run, t_other=t_new, mem_frac=mem_frac)
                xi_new = interference.xi(
                    new.model, running.model,
                    t_me=t_new, t_other=t_run, mem_frac=mem_frac)
            # share endpoint: both reconfigured, concurrent from kappa=0
            t_a0, t_b0 = pair_timeline(
                PairJob(t_iter=t_run, iters=rem_run, xi=xi_run),
                PairJob(t_iter=t_new, iters=new.iters, xi=xi_new), 0.0)
            avg0 = 0.5 * (t_a0 + t_b0)
            # sequential endpoint: donor untouched at its current b
            t_a1 = rem_run * t_run_cur
            avg1 = 0.5 * (t_a1 + (t_a1 + new.iters * t_new))
            if avg0 > avg1:
                continue   # reconfiguration cost eats the benefit
            cfg = DonorScaledConfig(
                share=True, donor_sub_batch=b_run, sub_batch=b_new,
                accum_steps=max(1, math.ceil(new.batch / b_new)),
                avg_jct=avg0, xi_run=xi_run, xi_new=xi_new)
            if best is None or cfg.avg_jct < best.avg_jct:
                best = cfg
            if fixed_xi is not None:
                # b-independent xi: the largest feasible b_new is optimal
                # for this b_run (same monotonicity as the plain sweep)
                break

    if best is None:
        return DonorScaledConfig(False, running.sub_batch, new.batch, 1,
                                 float("inf"))
    return best
