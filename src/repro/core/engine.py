"""Discrete-event engines behind :class:`repro.core.Simulator` (DESIGN.md §9).

The simulator core originally recomputed the next event time with a
linear ``min()`` scan over every running job on every event, and
refreshed every job's interference rate after every scheduling pass even
when nothing on its GPUs changed — an O(events x running x co-runners)
wall that dominates at datacenter trace sizes (the Philly/Helios regime).
Two engines now implement the same observable semantics:

* :class:`ScanEngine` — the pre-refactor reference, kept verbatim for
  equivalence testing (``tests/test_engine_equivalence.py``) and for the
  before/after microbench (``benchmarks/sim_throughput.py``).

* :class:`HeapEngine` — the default. An indexed binary heap of predicted
  finish events with lazy invalidation (per-job sequence numbers; stale
  entries are discarded on pop), a *dirty set* of jobs whose GPU
  co-runner sets actually changed (propagated from ``start_job`` /
  ``preempt_job`` / release-on-finish) so interference rates are only
  recomputed for those, and lazy progress/waiting accrual so events cost
  O(log running + |dirty|) instead of O(running x co-runners).

Both engines own the event clock, the pending/running queues, and the
progress accounting; the policy-facing :class:`repro.core.Simulator`
facade proxies its attributes here so schedulers keep their API.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from .job import Job, JobState

_EPS = 1e-9

# A job is complete when its remaining iterations drop below this
# fraction of its total (guards float drift near the finish time).
_FINISH_TOL = 1e-6


@dataclass
class SimResults:
    """Per-run results container.

    Contract on degenerate inputs (pinned by
    ``tests/test_sim_results_contract.py``): ``avg_jct`` /
    ``avg_queueing`` return **0.0 when the selection is empty** — an
    empty job list, or a large/small split with no members (e.g. a trace
    with only small jobs asked for ``large=True``). Callers averaging
    averages must treat 0.0-with-empty-selection as "no data", not as a
    measured zero. ``makespan`` is 0.0 for an empty run.
    """

    jobs: List[Job]
    makespan: float
    events: int
    name: str = ""

    # ------------------------------------------------------------------ #
    def _sel(self, large: Optional[bool]) -> List[Job]:
        if large is None:
            return self.jobs
        return [j for j in self.jobs if (j.gpus > 4) == large]

    def avg_jct(self, large: Optional[bool] = None) -> float:
        sel = self._sel(large)
        return sum(j.jct() for j in sel) / len(sel) if sel else 0.0

    def avg_queueing(self, large: Optional[bool] = None) -> float:
        sel = self._sel(large)
        return sum(j.queueing_delay() for j in sel) / len(sel) if sel else 0.0

    def jct_list(self) -> List[float]:
        return sorted(j.jct() for j in self.jobs)

    def summary(self) -> Dict[str, float]:
        return {
            "makespan": self.makespan,
            "avg_jct": self.avg_jct(),
            "avg_jct_large": self.avg_jct(True),
            "avg_jct_small": self.avg_jct(False),
            "avg_queue": self.avg_queueing(),
            "avg_queue_large": self.avg_queueing(True),
            "avg_queue_small": self.avg_queueing(False),
        }


class EngineBase:
    """Event clock, queues, and progress accounting shared by both engines.

    The constructor pulls its configuration from the owning
    :class:`repro.core.Simulator`; schedulers never see the engine —
    they interact with the facade, which proxies to it.
    """

    name = "base"

    def __init__(self, sim) -> None:
        self.sim = sim
        self.cluster = sim.cluster
        self.jobs: Dict[int, Job] = sim.jobs
        self.arrivals: List[Job] = sim.arrivals
        self.scheduler = sim.scheduler
        self.interference = sim.interference
        self.restart_penalty = sim.restart_penalty
        self.max_events = sim.max_events
        # DESIGN.md §13: restore co-tenants' sub-batches when a sharer
        # departs (opt-in; default keeps the seed semantics bit-exact)
        self.reconfig_on_release = getattr(sim, "reconfig_on_release", False)
        # DESIGN.md §16: fault injection. The timeline is precomputed by
        # the Simulator from the FaultModel seed alone, so every engine
        # and decision path replays the identical fault sequence; an
        # empty timeline leaves the event loop bit-identical to a run
        # with no fault model. Dynamic events (a chaos scheduler's
        # fail_server with a repair time) push into the same heap.
        self.fault_model = getattr(sim, "fault_model", None)
        self._fault_heap: List[tuple] = list(
            getattr(sim, "fault_events", ()) or ())
        heapq.heapify(self._fault_heap)
        self._fault_seq = len(self._fault_heap)

        self.time = 0.0
        self.pending: List[Job] = []
        self.running: Dict[int, Job] = {}
        # monotone preemption counter: the vectorized pending table
        # (repro.core.pass_batch) rebuilds when it moves, because a
        # requeued job re-enters the queue with a changed sort key
        self.preemptions_total = 0
        self._arrival_idx = 0
        self._blocked_until: Dict[int, float] = {}
        self._next_tick = (self.scheduler.tick_interval
                           if self.scheduler.tick_interval else None)
        self._events = 0
        self.log: List[tuple] = []

    # ------------------------------------------------------------------ #
    # Policy-facing mutations (invoked through the Simulator facade)
    # ------------------------------------------------------------------ #
    def start_job(self, job: Job, gpus: Sequence[int],
                  sub_batch: Optional[int] = None) -> None:
        if job.state == JobState.RUNNING:
            raise RuntimeError(f"job {job.jid} already running")
        gset = frozenset(gpus)
        want = job.alloc_gpus or job.gpus
        if len(gset) != want:
            raise RuntimeError(
                f"job {job.jid} needs {want} GPUs, got {len(gset)}")
        self.cluster.allocate(job.jid, gset)
        job.placement = gset
        if sub_batch is not None:
            job.sub_batch = int(sub_batch)
            # ceil, not round: for b that does not divide B the final
            # micro-batch absorbs the remainder (s*b >= B), keeping the
            # effective batch — and convergence — unchanged.
            job.accum_steps = max(1, math.ceil(job.batch / job.sub_batch))
        job.state = JobState.RUNNING
        job.start_time = self.time
        if job.first_start_time is None:
            job.first_start_time = self.time
        job.last_progress_at = self.time
        penalty = self.restart_penalty if job.preemptions > 0 else 0.0
        self._blocked_until[job.jid] = self.time + penalty
        self.running[job.jid] = job
        fl = self.cluster._flat
        if fl is not None:
            fl.note_start(job, self._blocked_until[job.jid])
        self._drop_pending(job)
        self._on_start(job)
        self.log.append((self.time, "start", job.jid, sorted(gset)))
        # the chosen (sub-batch, accumulation) configuration rides in a
        # separate entry so the 4-tuple "start" shape stays stable for
        # existing log consumers; replay (launch.cluster.plan_from_sim)
        # reads it to configure the physical job
        self.log.append((self.time, "config", job.jid,
                         int(job.sub_batch), int(job.accum_steps)))

    def preempt_job(self, job: Job) -> None:
        if job.state != JobState.RUNNING:
            raise RuntimeError(f"job {job.jid} not running")
        self._accrue(job, self.time)
        self._on_preempt(job)
        self.cluster.release(job.jid, job.placement)
        job.placement = frozenset()
        job.state = JobState.PENDING
        job.preemptions += 1
        job.current_rate = 0.0
        self.preemptions_total += 1
        fl = self.cluster._flat
        if fl is not None:
            fl.note_rate(job)
        del self.running[job.jid]
        self._blocked_until.pop(job.jid, None)
        self.pending.append(job)
        self._on_requeued(job)
        self.log.append((self.time, "preempt", job.jid))

    def reconfigure_job(self, job: Job, sub_batch: int) -> None:
        """Mid-run (τ, sub-batch) reconfiguration (DESIGN.md §13): the
        running job switches to ``sub_batch`` with ``s = ceil(B / b)``
        accumulation sub-steps — the effective batch is unchanged, only
        the iteration time (and hence the rate) moves. Progress is
        settled at the old rate first; the new rate takes effect from
        the current event time."""
        if job.state != JobState.RUNNING:
            raise RuntimeError(f"job {job.jid} not running")
        self._accrue(job, self.time)
        job.sub_batch = int(sub_batch)
        job.accum_steps = max(1, math.ceil(job.batch / job.sub_batch))
        fl = self.cluster._flat
        if fl is not None:
            fl.note_reconfig(job)
        self._on_reconfig(job)
        self.log.append((self.time, "reconfig", job.jid,
                         int(job.sub_batch), int(job.accum_steps)))

    def _restore_tenants(self, gpus) -> None:
        """When a job departs, surviving co-tenants on its GPUs may fit a
        larger sub-batch again (fewer accumulation sub-steps — strictly
        faster, same effective batch). Gated by ``reconfig_on_release``."""
        from .batch_scaling import candidate_sub_batches
        cap = self.cluster.gpu_capacity_bytes
        seen = set()
        for g in gpus:
            for jid in self.cluster.occupancy[g]:
                if jid in seen:
                    continue
                seen.add(jid)
                tenant = self.jobs[jid]
                # binding constraint: the most-loaded of the tenant's
                # GPUs, each loaded by the SUM of its co-tenants (> 2
                # tenants per GPU is reachable via custom schedulers)
                other_mem = 0.0
                for gg in tenant.placement:
                    load = sum(
                        self.jobs[o].perf.mem_bytes(self.jobs[o].sub_batch)
                        for o in self.cluster.occupancy[gg] if o != jid)
                    other_mem = max(other_mem, load)
                for b in candidate_sub_batches(tenant.batch):
                    if tenant.perf.fits(b, cap, other_mem=other_mem):
                        if b != tenant.sub_batch:
                            self.reconfigure_job(tenant, b)
                        break

    # ------------------------------------------------------------------ #
    # Fault events (DESIGN.md §16)
    # ------------------------------------------------------------------ #
    def fail_job(self, job: Job) -> None:
        """An injected fault kills the running ``job``: its progress is
        settled, then **rounded down to the last checkpoint boundary**
        (``FaultModel.checkpoint_interval``; no fault model / interval 0
        restarts the attempt from scratch), the lost work accounted in
        ``job.lost_iters``, and the job re-queued — it pays the restart
        penalty on its next start like a preempted job. Surviving
        co-tenants of its GPUs are gracefully rescaled to the largest
        sub-batch that fits again (``FaultModel.rescale_peers``) via the
        reconfig machinery instead of being killed."""
        if job.state != JobState.RUNNING:
            raise RuntimeError(f"job {job.jid} not running")
        self._accrue(job, self.time)
        fm = self.fault_model
        kept = fm.truncate_progress(job.iters_done) if fm is not None \
            else 0.0
        job.lost_iters += job.iters_done - kept
        job.iters_done = kept
        job.failures += 1
        self._on_preempt(job)
        self.cluster.release(job.jid, job.placement)
        released = job.placement
        job.placement = frozenset()
        job.state = JobState.PENDING
        job.preemptions += 1            # requeue invalidates sort keys
        job.current_rate = 0.0
        self.preemptions_total += 1
        fl = self.cluster._flat
        if fl is not None:
            fl.note_rate(job)
            fl.note_progress(job)
        del self.running[job.jid]
        self._blocked_until.pop(job.jid, None)
        self.pending.append(job)
        self._on_requeued(job)
        self.log.append((self.time, "fail_job", job.jid))
        if fm is None or fm.rescale_peers:
            self._restore_tenants(released)

    def fail_server(self, sid: int,
                    repair_after: Optional[float] = None) -> bool:
        """A server dies: every job holding one of its GPUs fails (in
        jid order, each via :meth:`fail_job`), then the server's GPUs
        leave the allocatable pool until the matching recover event.
        ``repair_after`` schedules that recovery onto the fault heap —
        callers injecting failures dynamically (the chaos harness) use
        it so the event loop knows capacity is coming back and does not
        mistake the lull for a deadlock. Returns False (and does
        nothing) if the server is already down."""
        cluster = self.cluster
        if sid < 0 or sid >= cluster.n_servers:
            raise ValueError(f"no server {sid}")
        if sid in cluster.down_servers:
            return False
        victims = sorted({jid for g in cluster.server_gpus(sid)
                          for jid in cluster.occupancy[g]})
        for jid in victims:
            self.fail_job(self.jobs[jid])
        cluster.set_server_down(sid)
        self.log.append((self.time, "fail_server", sid))
        if repair_after is not None:
            self._fault_seq = seq = self._fault_seq + 1
            heapq.heappush(self._fault_heap,
                           (self.time + repair_after, seq,
                            "recover_server", sid))
        return True

    def recover_server(self, sid: int) -> bool:
        """A failed server returns; its GPUs rejoin the free pool (the
        scheduling pass that follows may place onto them immediately).
        Already-recovered servers no-op (correlated kill timelines can
        carry overlapping repair windows; the earliest recover wins)."""
        if sid not in self.cluster.down_servers:
            return False
        self.cluster.set_server_up(sid)
        self.log.append((self.time, "recover_server", sid))
        return True

    def _next_fault_time(self) -> float:
        return self._fault_heap[0][0] if self._fault_heap else math.inf

    def _process_faults(self, now: float) -> None:
        """Apply every fault event due at ``now``. Events targeting a
        job that is not running, or a server already in the target
        state, are consumed silently — the timeline is precomputed, the
        cluster state is not."""
        fh = self._fault_heap
        while fh and fh[0][0] <= now + _EPS:
            _t, _seq, kind, target = heapq.heappop(fh)
            if kind == "fail_job":
                job = self.jobs.get(target)
                if job is not None and job.state is JobState.RUNNING:
                    self.fail_job(job)
            elif kind == "fail_server":
                self.fail_server(target)
            elif kind == "recover_server":
                self.recover_server(target)
            else:   # pragma: no cover - timeline is engine-generated
                raise ValueError(f"unknown fault event kind {kind!r}")

    # Engine-specific bookkeeping hooks -------------------------------- #
    def _drop_pending(self, job: Job) -> None:
        if job in self.pending:
            self.pending.remove(job)

    def _on_start(self, job: Job) -> None:
        pass

    def _on_preempt(self, job: Job) -> None:
        """Called while ``job`` still holds its GPUs (before release)."""

    def _on_requeued(self, job: Job) -> None:
        pass

    def _on_reconfig(self, job: Job) -> None:
        """Called after a running job's sub-batch changed (its own and
        its co-runners' rates need a refresh)."""

    # ------------------------------------------------------------------ #
    # Progress accounting
    # ------------------------------------------------------------------ #
    def effective_t_iter(self, job: Job) -> float:
        base = job.base_t_iter()
        occupancy = self.cluster.occupancy
        for g in job.placement:
            if len(occupancy[g]) > 1:
                break
        else:
            return base   # exclusive tenancy: no co-runner, xi = 1
        xi = 1.0
        for other_id in self.cluster.co_runners(job):
            other = self.jobs[other_id]
            mem = (job.perf.mem_bytes(job.sub_batch)
                   + other.perf.mem_bytes(other.sub_batch))
            xi = max(xi, self.interference.xi(
                job.model, other.model,
                t_me=base,
                t_other=other.solo_t_iter,
                mem_frac=mem / self.cluster.gpu_capacity_bytes))
        return base * xi

    def _accrue(self, job: Job, now: float) -> None:
        blocked_until = self._blocked_until.get(job.jid, 0.0)
        begin = max(job.last_progress_at, blocked_until)
        if now > begin and job.current_rate > 0:
            job.iters_done = min(
                job.iters, job.iters_done + (now - begin) * job.current_rate)
        if now > job.last_progress_at:
            job.attained_service += job.gpus * (now - job.last_progress_at)
            # time stalled on restart/migration counts as queueing delay
            stalled = min(now, blocked_until) - job.last_progress_at
            if stalled > 0:
                job.waiting_time += stalled
        job.last_progress_at = now
        fl = self.cluster._flat
        if fl is not None:
            fl.note_progress(job)

    def _predicted_finish(self, job: Job) -> float:
        if job.current_rate <= 0:
            return math.inf
        begin = max(self.time, self._blocked_until.get(job.jid, 0.0))
        return begin + job.remaining_iters / job.current_rate

    def remaining_at(self, job: Job) -> float:
        """``job``'s remaining iterations at the current event time,
        *without* materializing the accrual — the same float the next
        ``_accrue(job, self.time)`` would leave behind (identical
        IEEE-754 operation order). All sharing-decision paths read
        donors through this (or its vectorized mirror,
        ``pass_batch.FlatJobs.donor_rem``), so scalar, batched, and
        grid decisions see bit-identical donor state with no
        O(donors) pre-pass accrual sweep."""
        b = self._blocked_until.get(job.jid, 0.0)
        lp = job.last_progress_at
        begin = lp if lp > b else b
        done = job.iters_done
        now = self.time
        rate = job.current_rate
        if now > begin and rate > 0.0:
            adv = done + (now - begin) * rate
            iters = job.iters
            done = adv if adv < iters else iters
        rem = job.iters - done
        return rem if rem > 0.0 else 0.0

    def _results(self) -> SimResults:
        makespan = max((j.finish_time for j in self.jobs.values()),
                       default=0.0)
        return SimResults(jobs=list(self.jobs.values()), makespan=makespan,
                          events=self._events, name=self.scheduler.name)

    def run(self) -> SimResults:  # pragma: no cover - abstract
        raise NotImplementedError


# ---------------------------------------------------------------------- #
class ScanEngine(EngineBase):
    """The pre-refactor event loop: every event re-derives the next event
    time with a ``min()`` over all running jobs and refreshes every
    running job's rate. O(running x co-runners) per event; kept as the
    reference implementation."""

    name = "scan"

    def effective_t_iter(self, job: Job) -> float:
        # Pre-refactor body (no solo_t_iter memo on the co-runner
        # lookup): this engine is the frozen "before" the microbench
        # compares against. Only the t_other pricing follows the
        # final-microbatch-aware Eq. 7 (t_iter_sub) so both engines see
        # the same structural xi for non-divisor sub-batches — for the
        # divisor-only traces of the seed it is the identical value.
        base = job.base_t_iter()
        xi = 1.0
        for other_id in self.cluster.co_runners(job):
            other = self.jobs[other_id]
            mem = (job.perf.mem_bytes(job.sub_batch)
                   + other.perf.mem_bytes(other.sub_batch))
            xi = max(xi, self.interference.xi(
                job.model, other.model,
                t_me=base,
                t_other=other.perf.t_iter_sub(other.batch, other.sub_batch),
                mem_frac=mem / self.cluster.gpu_capacity_bytes))
        return base * xi

    def _refresh_rates(self) -> None:
        fl = self.cluster._flat
        for job in self.running.values():
            job.current_rate = 1.0 / self.effective_t_iter(job)
            if fl is not None:
                fl.note_rate(job)

    def run(self) -> SimResults:
        finished = 0
        total = len(self.jobs)
        self.scheduler.reset()
        self._refresh_rates()
        while finished < total:
            self._events += 1
            if self._events > self.max_events:
                raise RuntimeError(
                    f"simulator exceeded {self.max_events} events "
                    f"({finished}/{total} finished at t={self.time:.1f}; "
                    f"pending={len(self.pending)})")
            # -- next event time ---------------------------------------
            candidates: List[float] = []
            if self._arrival_idx < len(self.arrivals):
                candidates.append(self.arrivals[self._arrival_idx].arrival)
            for job in self.running.values():
                candidates.append(self._predicted_finish(job))
            if self._next_tick is not None:
                candidates.append(self._next_tick)
            if self._fault_heap:
                # A pending recover event is a real future event: jobs
                # may be stuck pending purely because servers are down.
                candidates.append(self._fault_heap[0][0])
            if not candidates:
                raise RuntimeError(
                    f"deadlock: {len(self.pending)} pending jobs, none "
                    f"running, no arrivals left (t={self.time:.1f})")
            t_next = min(candidates)
            if t_next < self.time - _EPS:
                raise RuntimeError("time went backwards")
            t_next = max(t_next, self.time)

            # -- advance all running jobs to t_next --------------------
            for job in list(self.running.values()):
                self._accrue(job, t_next)
            for job in self.pending:
                job.waiting_time += t_next - self.time
            self.time = t_next

            # -- completions -------------------------------------------
            for job in list(self.running.values()):
                if job.remaining_iters <= _FINISH_TOL * max(1.0, job.iters):
                    job.iters_done = job.iters
                    job.state = JobState.FINISHED
                    job.finish_time = self.time
                    released = job.placement
                    self.cluster.release(job.jid, released)
                    job.placement = frozenset()
                    del self.running[job.jid]
                    self._blocked_until.pop(job.jid, None)
                    finished += 1
                    self.log.append((self.time, "finish", job.jid))
                    if self.reconfig_on_release:
                        self._restore_tenants(released)

            # -- faults ------------------------------------------------
            self._process_faults(self.time)

            # -- arrivals ----------------------------------------------
            while (self._arrival_idx < len(self.arrivals)
                   and self.arrivals[self._arrival_idx].arrival
                       <= self.time + _EPS):
                job = self.arrivals[self._arrival_idx]
                self.pending.append(job)
                self._arrival_idx += 1
                self.log.append((self.time, "arrive", job.jid))

            # -- tick bookkeeping --------------------------------------
            tick_crossed = False
            if (self._next_tick is not None
                    and self.time + _EPS >= self._next_tick):
                self._next_tick = self.time + self.scheduler.tick_interval
                tick_crossed = True

            # -- schedule ----------------------------------------------
            if not self.scheduler.tick_only or tick_crossed:
                self.scheduler.schedule(self.sim)
            self._refresh_rates()

        return self._results()


# ---------------------------------------------------------------------- #
class HeapEngine(EngineBase):
    """Indexed event-heap engine (the default).

    Two heaps share one set of live entries, validated by per-job
    sequence numbers (``_entry_seq``):

    * ``_heap``      — keyed by the predicted finish time; drives the
                       next-event clock together with the next arrival
                       and the next scheduler tick.
    * ``_done_heap`` — keyed by the time at which the job's remaining
                       work drops inside the finish tolerance; replays
                       the scan engine's "complete at the first event
                       where remaining <= tol" semantics without the
                       per-event sweep.

    Rates are recomputed only for the dirty set — jobs whose co-runner
    sets changed via start/preempt/finish — and progress is accrued
    lazily: at rate changes, completion, preemption, and (for policies
    that declare ``reads_running_progress``) right before scheduling.
    """

    name = "heap"

    def __init__(self, sim) -> None:
        super().__init__(sim)
        self._heap: List[tuple] = []
        self._done_heap: List[tuple] = []
        self._entry_seq: Dict[int, int] = {}
        self._seq = 0
        self._dirty: set = set()
        self._pending_since: Dict[int, float] = {}

    # -- bookkeeping hooks --------------------------------------------- #
    def _drop_pending(self, job: Job) -> None:
        pending = self.pending
        for i, p in enumerate(pending):
            if p is job:
                del pending[i]
                break
        since = self._pending_since.pop(job.jid, None)
        if since is not None:
            job.waiting_time += self.time - since

    def _on_start(self, job: Job) -> None:
        dirty = self._dirty
        occupancy = self.cluster.occupancy
        dirty.add(job.jid)
        for g in job.placement:
            dirty.update(occupancy[g])

    def _on_preempt(self, job: Job) -> None:
        self._dirty.update(self.cluster.co_runners(job))
        self._dirty.discard(job.jid)

    def _on_requeued(self, job: Job) -> None:
        self._entry_seq.pop(job.jid, None)
        self._pending_since[job.jid] = self.time

    def _on_reconfig(self, job: Job) -> None:
        self._dirty.add(job.jid)
        self._dirty.update(self.cluster.co_runners(job))

    # ------------------------------------------------------------------ #
    def _refresh_dirty(self) -> None:
        """Recompute rates and (re)index finish events for jobs whose
        co-runner sets changed since the last event. Updates are staged
        and applied in one batch: when the batch rivals the heap size
        (mass preemption, big placement waves), both heaps are rebuilt
        with a single ``heapify`` over the still-valid entries instead
        of O(batch x log heap) pushes — pop order only depends on the
        entry keys, so results are unchanged."""
        dirty = self._dirty
        if not dirty:
            return
        running = self.running
        blocked = self._blocked_until
        entry_seq = self._entry_seq
        now = self.time
        fl = self.cluster._flat
        pushes: List[tuple] = []
        done_pushes: List[tuple] = []
        for jid in dirty:
            job = running.get(jid)
            if job is None:
                continue
            self._accrue(job, now)
            rate = 1.0 / self.effective_t_iter(job)
            job.current_rate = rate
            if fl is not None:
                fl.note_rate(job)
            b = blocked.get(jid, 0.0)
            begin = now if now > b else b
            rem = job.iters - job.iters_done
            if rem < 0.0:
                rem = 0.0
            tol = _FINISH_TOL * (job.iters if job.iters > 1.0 else 1.0)
            self._seq = seq = self._seq + 1
            entry_seq[jid] = seq
            pushes.append((begin + rem / rate, seq, jid))
            done_pushes.append((begin + (rem - tol) / rate, seq, jid))
        dirty.clear()
        heap = self._heap
        if len(pushes) > 64 and 4 * len(pushes) >= len(heap):
            live = [e for e in heap if entry_seq.get(e[2]) == e[1]]
            live.extend(pushes)
            heapq.heapify(live)
            self._heap = live
            done = [e for e in self._done_heap
                    if entry_seq.get(e[2]) == e[1]]
            done.extend(done_pushes)
            heapq.heapify(done)
            self._done_heap = done
        else:
            heappush = heapq.heappush
            for e in pushes:
                heappush(heap, e)
            done = self._done_heap
            for e in done_pushes:
                heappush(done, e)

    # ------------------------------------------------------------------ #
    def run(self) -> SimResults:
        sim = self.sim
        scheduler = self.scheduler
        cluster = self.cluster
        running = self.running
        arrivals = self.arrivals
        pending = self.pending
        next_heap = self._heap
        done_heap = self._done_heap
        entry_seq = self._entry_seq
        pending_since = self._pending_since
        dirty = self._dirty
        accrue = self._accrue
        heappop = heapq.heappop
        inf = math.inf
        tick_only = scheduler.tick_only
        reads_progress = getattr(scheduler, "reads_running_progress", True)
        donors_only = (getattr(scheduler, "progress_scope", "all")
                       == "donors")
        n_arrivals = len(arrivals)
        finished = 0
        total = len(self.jobs)
        scheduler.reset()

        while finished < total:
            self._events += 1
            if self._events > self.max_events:
                raise RuntimeError(
                    f"simulator exceeded {self.max_events} events "
                    f"({finished}/{total} finished at t={self.time:.1f}; "
                    f"pending={len(pending)})")

            # -- next event: valid heap top vs arrival vs tick ---------
            while next_heap and entry_seq.get(next_heap[0][2]) != next_heap[0][1]:
                heappop(next_heap)
            t_next = next_heap[0][0] if next_heap else inf
            if self._arrival_idx < n_arrivals:
                t_arr = arrivals[self._arrival_idx].arrival
                if t_arr < t_next:
                    t_next = t_arr
            if self._next_tick is not None and self._next_tick < t_next:
                t_next = self._next_tick
            if self._fault_heap and self._fault_heap[0][0] < t_next:
                # pending fault/recover events are real future events
                # (a recover may be the only thing unblocking the queue)
                t_next = self._fault_heap[0][0]
            if t_next == inf:
                raise RuntimeError(
                    f"deadlock: {len(pending)} pending jobs, none "
                    f"running, no arrivals left (t={self.time:.1f})")
            if t_next < self.time - _EPS:
                raise RuntimeError("time went backwards")
            if t_next < self.time:
                t_next = self.time
            self.time = now = t_next

            # -- completions: jobs due per the tolerance ordering ------
            while done_heap:
                key, seq, jid = done_heap[0]
                if entry_seq.get(jid) != seq:
                    heappop(done_heap)
                    continue
                if key > now:
                    break
                heappop(done_heap)
                del entry_seq[jid]
                job = running[jid]
                accrue(job, now)
                job.iters_done = job.iters
                job.state = JobState.FINISHED
                job.finish_time = now
                for g in job.placement:
                    dirty.update(cluster.occupancy[g])
                dirty.discard(jid)
                released = job.placement
                cluster.release(jid, released)
                job.placement = frozenset()
                del running[jid]
                self._blocked_until.pop(jid, None)
                finished += 1
                self.log.append((now, "finish", jid))
                if self.reconfig_on_release:
                    self._restore_tenants(released)

            # -- faults ------------------------------------------------
            self._process_faults(now)

            # -- arrivals ----------------------------------------------
            idx = self._arrival_idx
            while idx < n_arrivals and arrivals[idx].arrival <= now + _EPS:
                job = arrivals[idx]
                pending.append(job)
                pending_since[job.jid] = now
                idx += 1
                self.log.append((now, "arrive", job.jid))
            self._arrival_idx = idx

            # -- tick bookkeeping --------------------------------------
            tick_crossed = False
            if self._next_tick is not None and now + _EPS >= self._next_tick:
                self._next_tick = now + scheduler.tick_interval
                tick_crossed = True

            # -- schedule ----------------------------------------------
            if not tick_only or tick_crossed:
                if reads_progress:
                    if donors_only:
                        # Algorithm 1 only reads donors' remaining work;
                        # everyone else keeps accruing lazily at rate
                        # changes / completion (order-insensitive).
                        for jid in cluster.donor_jids():
                            accrue(running[jid], now)
                    else:
                        for job in running.values():
                            accrue(job, now)
                scheduler.schedule(sim)

            # -- incremental rate refresh ------------------------------
            self._refresh_dirty()

        return self._results()


ENGINES = {
    "scan": ScanEngine,
    "heap": HeapEngine,
}


def make_engine(name: str, sim) -> EngineBase:
    try:
        cls = ENGINES[name]
    except KeyError:
        raise ValueError(f"unknown simulator engine {name!r}; "
                         f"choose from {sorted(ENGINES)}") from None
    return cls(sim)
