"""Deterministic fault injection for the simulator (DESIGN.md §16).

Real multi-tenant clusters are not failure-free: Jeon et al.
(1901.05758) measure a large share of Philly GPU-hours burned by jobs
that fail and retry, and Hu et al. (2109.01313) report similar churn at
Helios scale. :class:`FaultModel` makes failures a first-class event
class without perturbing anything else:

* **Per-server MTBF** — each server draws an independent sequence of
  Weibull lifetimes (``weibull_shape=1`` is exponential; shapes < 1
  model infant mortality, > 1 wear-out), mean-normalized so the
  configured MTBF is the distribution mean regardless of shape. A
  failed server is down for ``server_repair`` seconds, then recovers.
  ``correlated_servers > 1`` turns every failure into a correlated kill
  of that many rack neighbours (``sid``, ``sid+1``, …) at the same
  instant — the switch/PDU failure mode.
* **Per-job failure rate** — each job draws a Poisson process of
  crash times (mean inter-arrival ``job_mtbf``); a crash only takes
  effect if the job is RUNNING at that instant, so the *effective*
  per-job hazard is proportional to its time on GPUs.

The whole timeline is **precomputed from the seed alone** (before the
simulation starts, independent of engine or decision path), so the heap
and scan engines — and the grid/batched/scalar decision paths — observe
the exact same fault sequence, and a model with both rates at zero
yields an empty timeline: the simulator's behaviour is bit-identical to
a run with no fault model at all.

Recovery semantics (implemented by :mod:`repro.core.engine`):

* a failed job is re-queued with its progress **rounded down to the
  last checkpoint** (``checkpoint_interval`` iterations; 0 restarts the
  attempt from scratch), the lost work accounted in ``Job.lost_iters``;
* a failed server kills every job holding one of its GPUs (they
  re-queue as above) and its GPUs leave the allocatable pool until the
  matching recover event;
* sharing peers of a failed job survive and — when ``rescale_peers`` —
  are restored to the largest sub-batch that fits the freed GPU via
  the existing mid-run reconfiguration machinery, rather than killed.

RNG streams are seeded with strings (``"{seed}/server/{sid}"``), which
``random.Random`` hashes via SHA-512 — stable across processes and
Python versions, unlike ``hash()``.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import List, Sequence, Tuple

__all__ = ["FaultEvent", "FaultModel"]

# (time, seq, kind, target) — kind is one of "fail_job" (target jid),
# "fail_server" / "recover_server" (target server id)
FaultEvent = Tuple[float, int, str, int]


@dataclass(frozen=True)
class FaultModel:
    """Seeded failure-process parameters. All rates default to 0 —
    a default-constructed model injects nothing."""

    seed: int = 0
    job_mtbf: float = 0.0          # mean s between crash draws per job; 0 off
    server_mtbf: float = 0.0       # mean lifetime per server (s); 0 off
    server_repair: float = 600.0   # downtime before a server recovers (s)
    weibull_shape: float = 1.0     # server lifetime shape; 1 = exponential
    correlated_servers: int = 1    # servers killed together per failure
    checkpoint_interval: float = 0.0   # iterations between checkpoints
    horizon: float = 30 * 24 * 3600.0  # stop sampling past this time
    rescale_peers: bool = True     # reconfig surviving co-tenants
    max_events_per_source: int = 10_000

    def __post_init__(self) -> None:
        if self.job_mtbf < 0 or self.server_mtbf < 0:
            raise ValueError("MTBF values must be >= 0")
        if self.server_repair <= 0:
            raise ValueError("server_repair must be > 0")
        if self.weibull_shape <= 0:
            raise ValueError("weibull_shape must be > 0")
        if self.correlated_servers < 1:
            raise ValueError("correlated_servers must be >= 1")
        if self.checkpoint_interval < 0:
            raise ValueError("checkpoint_interval must be >= 0")

    # ------------------------------------------------------------------ #
    @property
    def enabled(self) -> bool:
        return self.job_mtbf > 0 or self.server_mtbf > 0

    def timeline(self, n_servers: int, jids: Sequence[int]
                 ) -> List[FaultEvent]:
        """The full, sorted fault-event timeline for a cluster of
        ``n_servers`` and the given job ids. Pure in (model, inputs)."""
        events: List[Tuple[float, str, int]] = []
        if self.server_mtbf > 0 and n_servers > 0:
            # mean-normalize the Weibull so E[lifetime] == server_mtbf
            scale = self.server_mtbf / math.gamma(
                1.0 + 1.0 / self.weibull_shape)
            for sid in range(n_servers):
                rng = random.Random(f"{self.seed}/server/{sid}")
                t = 0.0
                for _ in range(self.max_events_per_source):
                    t += rng.weibullvariate(scale, self.weibull_shape)
                    if t >= self.horizon:
                        break
                    for i in range(self.correlated_servers):
                        target = (sid + i) % n_servers
                        events.append((t, "fail_server", target))
                        events.append((t + self.server_repair,
                                       "recover_server", target))
                    t += self.server_repair
        if self.job_mtbf > 0:
            for jid in jids:
                rng = random.Random(f"{self.seed}/job/{jid}")
                t = 0.0
                for _ in range(self.max_events_per_source):
                    t += rng.expovariate(1.0 / self.job_mtbf)
                    if t >= self.horizon:
                        break
                    events.append((t, "fail_job", int(jid)))
        events.sort()   # (time, kind, target): total, deterministic order
        return [(t, seq, kind, target)
                for seq, (t, kind, target) in enumerate(events)]

    def truncate_progress(self, iters_done: float) -> float:
        """Progress surviving a failure: rounded down to the last
        checkpoint boundary (with a tiny relative epsilon so engines
        that accrued the same progress modulo float noise land on the
        same checkpoint). No checkpointing → the attempt restarts from
        zero."""
        ck = self.checkpoint_interval
        if ck <= 0:
            return 0.0
        kept = math.floor(iters_done / ck + 1e-9) * ck
        return min(kept, iters_done)
