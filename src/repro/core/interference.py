"""Interference model — the xi ratios of Eqs. 5-6.

The paper measures xi per job pair on 2080 Ti GPUs (Fig. 3) and observes a
range up to ~6x. Without GPUs we provide:

  * a structural model for step-interleaved co-scheduling on a TPU slice
    (DESIGN.md §4): two jobs alternating (micro-)steps see
        xi_A ~= 1 + r * (t_B_sub / t_A_sub)
    where r in [0,1] is the overlap/contention coefficient (r=1 is strict
    time multiplexing) plus an HBM-pressure correction; and

  * a calibration table keyed by (model_a, model_b) that benchmarks can
    fill from "physical" CPU interleave measurements or paper-like values,
    plus a global override used for the Fig. 6b sweep.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

Key = Tuple[str, str]


def structural_xi(
    t_me: float,
    t_other: float,
    *,
    contention: float = 1.0,
    ratio_cap: Optional[float] = None,
    mem_frac: float = 0.0,
    hbm_pressure: float = 0.15,
) -> float:
    """THE structural interference model (DESIGN.md §4) — the single
    implementation behind both the scheduler's :meth:`InterferenceModel.xi`
    fallback and the physical testbed's analytic prediction
    (``repro.core.coschedule.structural_xi``).

    Strict time multiplexing of two programs gives
    ``xi_me = 1 + t_other / t_me``; ``contention`` in [0, 1] scales the
    co-tenant term (1 = no overlap between the programs, < 1 credits
    pipelined compute/collective overlap), ``ratio_cap`` optionally clamps
    the timing ratio (the scheduler's table-free fallback caps it at 4 so
    one pathological pairing cannot dominate a whole schedule), and an
    HBM-pressure term penalizes near-capacity combined working sets.
    """
    ratio = t_other / max(t_me, 1e-12)
    if ratio_cap is not None and ratio > ratio_cap:
        ratio = ratio_cap
    xi = 1.0 + contention * ratio
    if mem_frac > 0.8:
        xi += hbm_pressure * (mem_frac - 0.8) / 0.2
    return xi


@dataclass
class InterferenceModel:
    """Returns (xi_for_me, xi_for_other) when ``me`` shares GPUs with
    ``other``. Priority: global override > pair table > structural model."""

    # contention coefficient of the structural model; r=1 -> pure
    # time-multiplexing (xi_A = 1 + t_B/t_A), r<1 -> partial overlap.
    contention: float = 0.35
    # extra slowdown when combined working set approaches HBM capacity
    hbm_pressure: float = 0.15
    table: Dict[Key, Tuple[float, float]] = field(default_factory=dict)
    global_xi: Optional[float] = None   # Fig. 6b style injection

    def set_pair(self, a: str, b: str, xi_a: float, xi_b: float) -> None:
        self.table[(a, b)] = (xi_a, xi_b)
        self.table[(b, a)] = (xi_b, xi_a)

    def pair_fixed(self, me: str, other: str) -> Optional[Tuple[float, float]]:
        """(xi_me, xi_other) when both directions are independent of
        timing/memory — a global override or a two-way table hit — so
        callers sweeping sub-batches can hoist the lookup out of the
        loop. None when the structural model applies to either side."""
        if self.global_xi is not None:
            return self.global_xi, self.global_xi
        a = self.table.get((me, other))
        if a is None:
            return None
        b = self.table.get((other, me))
        if b is None:
            return None
        return a[0], b[0]

    def xi(
        self,
        me: str,
        other: str,
        *,
        t_me: float = 1.0,
        t_other: float = 1.0,
        mem_frac: float = 0.0,
    ) -> float:
        """Interference ratio applied to ``me``'s iteration time.

        ``t_me``/``t_other`` are the solo iteration times (used by the
        structural model), ``mem_frac`` the fraction of device memory used
        by the pair together."""
        if self.global_xi is not None:
            return self.global_xi
        hit = self.table.get((me, other))
        if hit is not None:
            return hit[0]
        return structural_xi(t_me, t_other, contention=self.contention,
                             ratio_cap=4.0, mem_frac=mem_frac,
                             hbm_pressure=self.hbm_pressure)

    # ------------------------------------------------------------------ #
    @classmethod
    def from_artifact(cls, artifact: Union[str, Dict],
                      **overrides) -> "InterferenceModel":
        """Build the pair table from a calibration artifact — the closed
        loop of DESIGN.md §13: xi measured by really co-executing job
        pairs on this host (``repro.core.calibration``) replaces the
        synthesized :func:`paper_interference_model` table.

        ``artifact`` is either the payload dict or a path to the
        versioned ``calibration.json``; its schema is owned by
        :mod:`repro.core.calibration`."""
        from .calibration import CALIBRATION_VERSION, load_artifact
        if isinstance(artifact, str):
            if not os.path.exists(artifact):
                raise FileNotFoundError(
                    f"calibration artifact not found: {artifact!r} "
                    "(run `python -m benchmarks.xi_calibration` to "
                    "produce one)")
            artifact = load_artifact(artifact)
        version = artifact.get("version")
        if version != CALIBRATION_VERSION:
            raise ValueError(
                f"unsupported calibration artifact version {version!r}")
        model = cls(**overrides)
        for entry in artifact["pairs"].values():
            model.set_pair(entry["a"], entry["b"],
                           float(entry["xi_a"]), float(entry["xi_b"]))
        return model


# Paper-like pair table for the six Pollux/paper DL tasks. The paper does
# not publish the raw xi matrix; these values are synthesized to match the
# reported qualitative structure (range up to ~6, compute-bound pairs ~1.6-2,
# comm-bound pairs lighter, memory-heavy pairs severe). Used by the
# paper-faithful benchmarks; the Fig. 6b sweep overrides them globally.
PAPER_TASKS = ("bert", "cifar10", "deepspeech2", "imagenet", "ncf", "yolov3")


def paper_interference_model() -> InterferenceModel:
    m = InterferenceModel()
    base = {
        # (a, b): xi_a when a shares with b  (diagonal = self-pairing).
        # Mostly mild (1.1-1.5); a few bad pairings (compute-saturating
        # YoloV3/ImageNet combos) reach 2-6x, matching the reported range.
        ("bert", "bert"): 1.55, ("bert", "cifar10"): 1.15,
        ("bert", "deepspeech2"): 1.30, ("bert", "imagenet"): 1.45,
        ("bert", "ncf"): 1.20, ("bert", "yolov3"): 1.80,
        ("cifar10", "cifar10"): 1.12, ("cifar10", "bert"): 1.25,
        ("cifar10", "deepspeech2"): 1.20, ("cifar10", "imagenet"): 1.30,
        ("cifar10", "ncf"): 1.10, ("cifar10", "yolov3"): 1.45,
        ("deepspeech2", "deepspeech2"): 1.40, ("deepspeech2", "bert"): 1.35,
        ("deepspeech2", "cifar10"): 1.18, ("deepspeech2", "imagenet"): 1.35,
        ("deepspeech2", "ncf"): 1.15, ("deepspeech2", "yolov3"): 1.60,
        ("imagenet", "imagenet"): 1.75, ("imagenet", "bert"): 1.50,
        ("imagenet", "cifar10"): 1.25, ("imagenet", "deepspeech2"): 1.40,
        ("imagenet", "ncf"): 1.18, ("imagenet", "yolov3"): 2.30,
        ("ncf", "ncf"): 1.15, ("ncf", "bert"): 1.25,
        ("ncf", "cifar10"): 1.10, ("ncf", "deepspeech2"): 1.18,
        ("ncf", "imagenet"): 1.30, ("ncf", "yolov3"): 1.40,
        ("yolov3", "yolov3"): 5.8, ("yolov3", "bert"): 1.95,
        ("yolov3", "cifar10"): 1.50, ("yolov3", "deepspeech2"): 1.75,
        ("yolov3", "imagenet"): 2.60, ("yolov3", "ncf"): 1.45,
    }
    for (a, b), xi_a in base.items():
        xi_b = base.get((b, a), xi_a)
        m.table[(a, b)] = (xi_a, xi_b)
    return m
