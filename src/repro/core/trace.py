"""Workload generation following the Microsoft/Philly trace shape used by
the paper (Section VI-A): GPU-demand and iteration-count distributions,
Poisson arrivals, model mix over the six Pollux tasks (paper-faithful) or
the ten assigned architectures (TPU-cluster mode); plus a
datacenter-scale generator (:func:`datacenter_trace`) with a
heavy-tailed demand distribution for the Philly/Helios-regime
scheduling benchmarks (thousands of jobs, thousands of GPUs)."""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from .job import Job
from .perf_model import GPU_2080TI, HardwareSpec
from .tasks import PAPER_TASK_PROFILES, TaskProfile


@dataclass(frozen=True)
class TraceConfig:
    n_jobs: int = 240
    seed: int = 0
    mean_interarrival: float = 90.0          # Poisson arrivals (s)
    # Philly-like GPU demand distribution (paper: >4 GPUs == "large")
    gpu_demand: Sequence[tuple[int, float]] = (
        (1, 0.30), (2, 0.20), (4, 0.20), (8, 0.15), (12, 0.05), (16, 0.10))
    min_iters: int = 100
    max_iters: int = 5000
    log_uniform_iters: bool = True
    tasks: Optional[Dict[str, TaskProfile]] = None
    hw: HardwareSpec = GPU_2080TI
    task_weights: Optional[Dict[str, float]] = None


def _sample_iters(rng: random.Random, cfg: TraceConfig) -> int:
    if cfg.log_uniform_iters:
        lo, hi = math.log(cfg.min_iters), math.log(cfg.max_iters)
        return int(round(math.exp(rng.uniform(lo, hi))))
    return rng.randint(cfg.min_iters, cfg.max_iters)


def _sample_gpus(rng: random.Random, cfg: TraceConfig) -> int:
    r = rng.random()
    acc = 0.0
    for gpus, p in cfg.gpu_demand:
        acc += p
        if r <= acc:
            return gpus
    return cfg.gpu_demand[-1][0]


def generate_trace(cfg: TraceConfig) -> List[Job]:
    rng = random.Random(cfg.seed)
    tasks = cfg.tasks or PAPER_TASK_PROFILES
    names = sorted(tasks)
    weights = ([cfg.task_weights.get(n, 1.0) for n in names]
               if cfg.task_weights else None)
    jobs: List[Job] = []
    t = 0.0
    for jid in range(cfg.n_jobs):
        t += rng.expovariate(1.0 / cfg.mean_interarrival)
        name = rng.choices(names, weights=weights)[0]
        prof = tasks[name]
        gpus = _sample_gpus(rng, cfg)
        jobs.append(Job(
            jid=jid,
            model=name,
            arrival=t,
            gpus=gpus,
            iters=float(_sample_iters(rng, cfg)),
            batch=prof.default_batch,
            perf=prof.perf_params(gpus, cfg.hw),
        ))
    return jobs


def physical_trace(seed: int = 0) -> List[Job]:
    """The 30-job scaled-down trace of the physical 16-GPU experiment:
    20 jobs with <= 8 GPUs, 10 jobs with 12 or 16 GPUs, iterations in
    [100, 5000] (Section VI-A)."""
    rng = random.Random(seed)
    jobs: List[Job] = []
    t = 0.0
    specs = [rng.choice([1, 2, 4, 8]) for _ in range(20)] + \
            [rng.choice([12, 16]) for _ in range(10)]
    rng.shuffle(specs)
    names = sorted(PAPER_TASK_PROFILES)
    for jid, gpus in enumerate(specs):
        t += rng.expovariate(1.0 / 30.0)
        name = rng.choice(names)
        prof = PAPER_TASK_PROFILES[name]
        iters = int(round(math.exp(rng.uniform(math.log(100),
                                               math.log(5000)))))
        jobs.append(Job(
            jid=jid, model=name, arrival=t, gpus=gpus, iters=float(iters),
            batch=prof.default_batch,
            perf=prof.perf_params(gpus, GPU_2080TI),
        ))
    return jobs


# Heavy-tailed Philly/Helios-like demand mix: most jobs are small, a
# long tail of 32-128 GPU jobs carries a large share of the GPU-hours.
DATACENTER_GPU_DEMAND: Sequence[tuple[int, float]] = (
    (1, 0.32), (2, 0.22), (4, 0.17), (8, 0.12), (16, 0.08),
    (32, 0.05), (64, 0.03), (128, 0.01))


def datacenter_trace(
    n_jobs: int = 5000,
    seed: int = 0,
    n_gpus: int = 1024,
    utilization: float = 0.7,
    gpu_demand: Sequence[tuple[int, float]] = DATACENTER_GPU_DEMAND,
    min_iters: int = 200,
    max_iters: int = 50000,
    tasks: Optional[Dict[str, TaskProfile]] = None,
    hw: HardwareSpec = GPU_2080TI,
) -> List[Job]:
    """Datacenter-scale workload (configurable up to ~10k jobs / 4096
    GPUs): heavy-tailed GPU demand, log-uniform iteration counts, and
    Poisson arrivals whose rate is *derived from the target cluster
    utilization* — the offered load (solo GPU-seconds per wall-second)
    is ``utilization * n_gpus`` whatever the cluster size, so one knob
    sweeps the {64, 256, 1024, 4096}-GPU scenarios of
    ``benchmarks/sched_decision_bench.py``. Fully determined by the
    arguments (same seed -> same trace)."""
    rng = random.Random(seed)
    tasks = tasks or PAPER_TASK_PROFILES
    names = sorted(tasks)
    lo, hi = math.log(min_iters), math.log(max_iters)
    specs = []
    total_gpu_seconds = 0.0
    for _ in range(n_jobs):
        name = rng.choice(names)
        prof = tasks[name]
        r = rng.random()
        acc = 0.0
        gpus = gpu_demand[-1][0]
        for g, p in gpu_demand:
            acc += p
            if r <= acc:
                gpus = g
                break
        gpus = min(gpus, n_gpus)
        iters = int(round(math.exp(rng.uniform(lo, hi))))
        perf = prof.perf_params(gpus, hw)
        est = perf.t_iter(prof.default_batch) * iters
        total_gpu_seconds += gpus * est
        specs.append((name, gpus, iters, perf, prof.default_batch))
    # arrival horizon that offers `utilization * n_gpus` GPU-seconds of
    # solo work per wall-second
    horizon = total_gpu_seconds / (n_gpus * max(utilization, 1e-9))
    mean_interarrival = horizon / n_jobs
    jobs: List[Job] = []
    t = 0.0
    for jid, (name, gpus, iters, perf, batch) in enumerate(specs):
        t += rng.expovariate(1.0 / mean_interarrival)
        jobs.append(Job(jid=jid, model=name, arrival=t, gpus=gpus,
                        iters=float(iters), batch=batch, perf=perf))
    return jobs


# Philly-shaped demand mix (Jeon et al., ATC'19 Fig. 2): the vast
# majority of jobs are 1-GPU, and the multi-GPU tail is thinner than the
# synthetic datacenter mix above — but it still carries most GPU-hours.
PHILLY_GPU_DEMAND: Sequence[tuple[int, float]] = (
    (1, 0.55), (2, 0.16), (4, 0.12), (8, 0.10), (16, 0.04),
    (32, 0.02), (64, 0.008), (128, 0.002))


def philly_trace(
    n_jobs: int = 5000,
    seed: int = 0,
    n_gpus: int = 1024,
    utilization: float = 0.7,
    gpu_demand: Sequence[tuple[int, float]] = PHILLY_GPU_DEMAND,
    median_seconds: float = 600.0,
    sigma: float = 1.8,
    min_seconds: float = 30.0,
    max_seconds: float = 30.0 * 86400.0,
    diurnal_amplitude: float = 0.5,
    tasks: Optional[Dict[str, TaskProfile]] = None,
    hw: HardwareSpec = GPU_2080TI,
) -> List[Job]:
    """Philly/Helios-shaped replay trace for capacity-planning sweeps
    (DESIGN.md §14; ``benchmarks/sim_scale.py``).

    Three distributional signatures of the production traces, all
    derived from the published trace analyses rather than raw replay:

    * **Job sizes** follow ``PHILLY_GPU_DEMAND`` — mostly 1-GPU jobs
      with a thin 32-128 GPU tail.
    * **Durations** are log-normal with a heavy tail
      (``median_seconds`` median, ``sigma`` log-std, clipped to
      ``[min_seconds, max_seconds]``); the iteration count is whatever
      delivers that *solo* duration on the sampled task's perf model,
      so the realized JCT distribution matches the target under
      no-sharing, no-queueing conditions.
    * **Arrivals** are a diurnal nonhomogeneous Poisson process,
      ``lam(t) = lam0 * (1 + amp * sin(2*pi*(t - 6h) / 24h))`` — peak
      at local noon, trough at midnight — realized by thinning against
      ``lam_max = lam0 * (1 + amp)``. The base rate ``lam0`` is derived
      from the target cluster ``utilization`` exactly like
      :func:`datacenter_trace`, so ``utilization=0.77`` answers "what
      does +10% load do to p95 queueing?" against a 0.7 baseline.

    Fully determined by the arguments (same seed -> same trace): specs
    are sampled first from a single sequential RNG stream, then the
    arrival process consumes the remainder of the stream.
    """
    rng = random.Random(seed)
    tasks = tasks or PAPER_TASK_PROFILES
    names = sorted(tasks)
    mu = math.log(median_seconds)
    specs = []
    total_gpu_seconds = 0.0
    for _ in range(n_jobs):
        name = rng.choice(names)
        prof = tasks[name]
        r = rng.random()
        acc = 0.0
        gpus = gpu_demand[-1][0]
        for g, p in gpu_demand:
            acc += p
            if r <= acc:
                gpus = g
                break
        gpus = min(gpus, n_gpus)
        dur = min(max_seconds, max(min_seconds, rng.lognormvariate(mu, sigma)))
        perf = prof.perf_params(gpus, hw)
        t_iter = perf.t_iter(prof.default_batch)
        iters = max(10, int(round(dur / t_iter)))
        total_gpu_seconds += gpus * iters * t_iter
        specs.append((name, gpus, iters, perf, prof.default_batch))
    # base rate offering `utilization * n_gpus` GPU-seconds of solo work
    # per wall-second, averaged over the diurnal cycle (the sine term
    # integrates to zero over whole days)
    horizon = total_gpu_seconds / (n_gpus * max(utilization, 1e-9))
    lam0 = n_jobs / max(horizon, 1e-9)
    amp = min(max(diurnal_amplitude, 0.0), 1.0)
    lam_max = lam0 * (1.0 + amp)
    day = 86400.0

    def rate(t: float) -> float:
        return lam0 * (1.0 + amp * math.sin(2.0 * math.pi * (t - 21600.0)
                                            / day))

    jobs: List[Job] = []
    t = 0.0
    for jid, (name, gpus, iters, perf, batch) in enumerate(specs):
        # thinning: candidate points at rate lam_max, accepted with
        # probability rate(t) / lam_max
        while True:
            t += rng.expovariate(lam_max)
            if rng.random() * lam_max <= rate(t):
                break
        jobs.append(Job(jid=jid, model=name, arrival=t, gpus=gpus,
                        iters=float(iters), batch=batch, perf=perf))
    return jobs


def calibrated_trace(payload, n_jobs: int = 30, seed: int = 0,
                     min_iters: int = 50, max_iters: int = 1000,
                     gpu_demand: Sequence[tuple[int, float]] = (
                         (1, 0.5), (2, 0.3), (4, 0.2)),
                     load: float = 4.0) -> List[Job]:
    """Workload over HOST-MEASURED profiles (DESIGN.md §13): job perf
    comes from a calibration artifact (``repro.core.calibration``), so
    simulated seconds are this host's seconds. Interarrival times scale
    with the measured mean iteration time — ``load`` is roughly how many
    solo jobs' worth of work arrives per mean job duration."""
    from .calibration import profiles_from_artifact
    profiles = profiles_from_artifact(payload)
    names = sorted(profiles)
    rng = random.Random(seed)
    lo, hi = math.log(min_iters), math.log(max_iters)
    mean_iters = math.exp(0.5 * (lo + hi))
    mean_t_iter = sum(
        p.params.t_iter(p.default_batch) for p in profiles.values()
    ) / len(profiles)
    mean_interarrival = mean_iters * mean_t_iter / max(load, 1e-9)
    jobs: List[Job] = []
    t = 0.0
    for jid in range(n_jobs):
        t += rng.expovariate(1.0 / mean_interarrival)
        prof = profiles[rng.choice(names)]
        r = rng.random()
        acc = 0.0
        gpus = gpu_demand[-1][0]
        for g, p in gpu_demand:
            acc += p
            if r <= acc:
                gpus = g
                break
        iters = int(round(math.exp(rng.uniform(lo, hi))))
        jobs.append(Job(
            jid=jid, model=prof.name, arrival=t, gpus=gpus,
            iters=float(iters), batch=prof.default_batch,
            perf=prof.perf_params(gpus),
        ))
    return jobs


def simulation_trace(n_jobs: int = 240, seed: int = 0,
                     load_scale: float = 1.0,
                     tasks: Optional[Dict[str, TaskProfile]] = None,
                     hw: HardwareSpec = GPU_2080TI) -> List[Job]:
    """The 240/480-job simulation workloads (Tables III/IV); ``load_scale``
    compresses/stretches interarrival times for the Fig. 6a sweep."""
    cfg = TraceConfig(
        n_jobs=n_jobs,
        seed=seed,
        mean_interarrival=90.0 / max(load_scale, 1e-9),
        max_iters=20000,
        min_iters=200,
        tasks=tasks,
        hw=hw,
    )
    return generate_trace(cfg)
