"""Parallel scenario sweeps — policy x load x seed grids fanned across
worker processes (DESIGN.md §9).

A scenario is a frozen :class:`ScenarioSpec`; every worker rebuilds its
trace deterministically from the spec fields alone (nothing is shared
between processes), so a sweep's aggregate output is byte-identical
however it is partitioned across workers — including ``workers=1``.
``tests/test_sweep.py`` asserts this. The paper-table benchmarks
(``benchmarks/table3_240.py``, ``fig4_fig5``, ``fig6a``, ``fig6b``,
``table4``) and the ``benchmarks/sweep.py`` CLI are thin wrappers over
:func:`grid` + :func:`run_sweep`.
"""
from __future__ import annotations

import csv
import io
import json
import math
import multiprocessing
import os
import time
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .interference import InterferenceModel, paper_interference_model
from .job import ClusterState
from .schedulers import ALL_POLICIES, make_scheduler
from .simulator import Simulator
from .trace import (datacenter_trace, philly_trace, physical_trace,
                    simulation_trace)

__all__ = [
    "ScenarioSpec", "grid", "normalize_policy", "run_scenario",
    "run_sweep", "rows_by_policy", "summary_table", "to_canonical_json",
    "write_csv", "write_json",
]

# row keys that vary between runs and are excluded from canonical output
_NONDETERMINISTIC = ("wall_seconds",)


def normalize_policy(name: str) -> str:
    """Accept ``sjf_bsbf`` and ``SJF-BSBF`` spellings for ``sjf-bsbf``."""
    name = name.strip().lower().replace("_", "-")
    if name not in ALL_POLICIES:
        raise ValueError(f"unknown policy {name!r}; "
                         f"choose from {sorted(ALL_POLICIES)}")
    return name


@dataclass(frozen=True)
class ScenarioSpec:
    """One simulation scenario, fully determined by its fields (the
    worker regenerates the trace from ``seed``/``n_jobs``/``load_scale``,
    so the same spec always produces the same row)."""

    policy: str
    n_jobs: int = 240
    seed: int = 0
    # trace="datacenter"/"philly" read load_scale as a multiplier on the
    # 0.7 target cluster utilization of the corresponding trace builder
    load_scale: float = 1.0
    # "simulation" | "physical" | "datacenter" | "philly"
    trace: str = "simulation"
    n_servers: int = 16
    gpus_per_server: int = 4
    capacity_gb: float = 11.0
    global_xi: Optional[float] = None  # Fig. 6b style xi injection
    # None lets the Simulator resolve (REPRO_SIM_ENGINE env, else heap)
    engine: Optional[str] = None
    # sharing-decision path: None -> Simulator default (REPRO_SIM_DECISION
    # env, else the vectorized "grid" pass); "scalar" for the reference
    decision: Optional[str] = None
    collect: Tuple[str, ...] = ()      # extra per-job metrics (below)
    tag: str = ""                      # free-form grouping label


def grid(policies: Sequence[str], *, seeds: Sequence[int] = (0,),
         loads: Sequence[float] = (1.0,), **common) -> List[ScenarioSpec]:
    """The policy x seed x load cross product; remaining spec fields come
    from ``common``."""
    return [
        ScenarioSpec(policy=normalize_policy(p), seed=seed,
                     load_scale=load, **common)
        for load in loads for seed in seeds for p in policies
    ]


# ---------------------------------------------------------------------- #
# Per-job metric collectors (computed in the worker so only small rows
# cross the process boundary)
# ---------------------------------------------------------------------- #
def _percentile(sorted_vals: List[float], q: float) -> float:
    """numpy's default linear-interpolation percentile, dependency-free."""
    n = len(sorted_vals)
    if n == 1:
        return float(sorted_vals[0])
    rank = q / 100.0 * (n - 1)
    lo = math.floor(rank)
    hi = math.ceil(rank)
    if lo == hi:
        return float(sorted_vals[lo])
    return float(sorted_vals[lo]
                 + (sorted_vals[hi] - sorted_vals[lo]) * (rank - lo))


def _jct_deciles(res) -> List[float]:
    jcts = res.jct_list()
    return [_percentile(jcts, q) for q in range(10, 101, 10)]


def _queue_by_model(res) -> Dict[str, float]:
    acc: Dict[str, List[float]] = {}
    for j in res.jobs:
        acc.setdefault(j.model, []).append(j.queueing_delay())
    return {m: sum(v) / len(v) for m, v in sorted(acc.items())}


def _jct_list(res) -> List[float]:
    return res.jct_list()


def _queue_percentiles(res) -> Dict[str, float]:
    """p50/p90/p95/p99 queueing delay — the capacity-planning readout of
    ``benchmarks/sim_scale.py`` ("what does +10% load do to p95?")."""
    delays = sorted(j.queueing_delay() for j in res.jobs)
    if not delays:
        return {"p50": 0.0, "p90": 0.0, "p95": 0.0, "p99": 0.0}
    return {f"p{q}": _percentile(delays, q) for q in (50, 90, 95, 99)}


_COLLECTORS = {
    "jct_deciles": _jct_deciles,
    "queue_by_model": _queue_by_model,
    "jct_list": _jct_list,
    "queue_percentiles": _queue_percentiles,
}


# ---------------------------------------------------------------------- #
def _build_jobs(spec: ScenarioSpec):
    if spec.trace == "physical":
        if spec.load_scale != 1.0:
            raise ValueError(
                "the physical trace has a fixed 30-job arrival pattern; "
                "load_scale is only supported for trace='simulation'")
        return physical_trace(seed=spec.seed)
    if spec.trace == "simulation":
        return simulation_trace(n_jobs=spec.n_jobs, seed=spec.seed,
                                load_scale=spec.load_scale)
    if spec.trace == "datacenter":
        return datacenter_trace(
            n_jobs=spec.n_jobs, seed=spec.seed,
            n_gpus=spec.n_servers * spec.gpus_per_server,
            utilization=0.7 * spec.load_scale)
    if spec.trace == "philly":
        return philly_trace(
            n_jobs=spec.n_jobs, seed=spec.seed,
            n_gpus=spec.n_servers * spec.gpus_per_server,
            utilization=0.7 * spec.load_scale)
    raise ValueError(f"unknown trace kind {spec.trace!r}")


def run_scenario(spec: ScenarioSpec) -> Dict:
    """Run one scenario and reduce it to a plain-dict row (module-level so
    multiprocessing can pickle it)."""
    for metric in spec.collect:
        if metric not in _COLLECTORS:
            raise ValueError(f"unknown collect metric {metric!r}; "
                             f"choose from {sorted(_COLLECTORS)}")
    jobs = _build_jobs(spec)
    cluster = ClusterState(
        n_servers=spec.n_servers,
        gpus_per_server=spec.gpus_per_server,
        gpu_capacity_bytes=spec.capacity_gb * 2 ** 30)
    interference = (InterferenceModel(global_xi=spec.global_xi)
                    if spec.global_xi is not None
                    else paper_interference_model())
    sim = Simulator(cluster, jobs, make_scheduler(spec.policy),
                    interference=interference, engine=spec.engine,
                    decision=spec.decision)
    t0 = time.time()
    res = sim.run()
    row = dict(asdict(spec))
    row["n_jobs"] = len(jobs)   # physical traces fix their own job count
    row["engine"] = sim.engine_name   # record the resolved engine
    row["decision"] = sim.decision_path   # record the resolved path
    row["collect"] = list(spec.collect)
    row["events"] = res.events
    row["summary"] = res.summary()
    for metric in spec.collect:
        row[metric] = _COLLECTORS[metric](res)
    row["wall_seconds"] = time.time() - t0
    return row


def _export_import_path() -> None:
    """Make sure spawned workers can import ``repro`` even when the
    parent got it from pytest's ``pythonpath`` hook or an ad-hoc
    ``sys.path`` edit rather than an install or the PYTHONPATH env."""
    src_root = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    existing = os.environ.get("PYTHONPATH", "")
    if src_root not in existing.split(os.pathsep):
        os.environ["PYTHONPATH"] = (
            src_root + (os.pathsep + existing if existing else ""))


def run_sweep(specs: Sequence[ScenarioSpec], workers: Optional[int] = None,
              ) -> List[Dict]:
    """Run every scenario, fanning across ``workers`` processes (default:
    one per scenario up to the CPU count). Rows come back in spec order
    regardless of which worker finished first.

    Workers are *spawned*, not forked: callers routinely have JAX (and
    its thread pools) imported, and forking a multithreaded parent can
    deadlock the child."""
    specs = list(specs)
    if workers is None:
        workers = min(len(specs), os.cpu_count() or 1)
    if workers <= 1 or len(specs) <= 1:
        return [run_scenario(s) for s in specs]
    _export_import_path()
    ctx = multiprocessing.get_context("spawn")
    with ctx.Pool(processes=min(workers, len(specs))) as pool:
        return pool.map(run_scenario, specs, chunksize=1)


# ---------------------------------------------------------------------- #
# Aggregation / serialization
# ---------------------------------------------------------------------- #
def rows_by_policy(rows: Sequence[Dict]) -> Dict[str, Dict]:
    """{policy: summary} for single-seed single-load sweeps (the paper
    tables' payload shape)."""
    out: Dict[str, Dict] = {}
    for row in rows:
        out[row["policy"]] = row["summary"]
    return out


def to_canonical_json(rows: Sequence[Dict]) -> bytes:
    """Deterministic serialization: drops wall-clock fields, sorts keys.
    Two runs of the same sweep produce byte-identical output whatever
    the worker count."""
    canonical = [{k: v for k, v in row.items()
                  if k not in _NONDETERMINISTIC} for row in rows]
    return (json.dumps(canonical, sort_keys=True, indent=1) + "\n").encode()


def write_json(rows: Sequence[Dict], path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "wb") as f:
        f.write(to_canonical_json(rows))
    return path


_CSV_FIELDS = ("tag", "trace", "policy", "n_jobs", "seed", "load_scale",
               "global_xi", "engine", "decision", "events")


def write_csv(rows: Sequence[Dict], path: str) -> str:
    """Flat CSV: spec fields + one column per summary metric."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    metric_keys: List[str] = []
    for row in rows:
        for k in row["summary"]:
            if k not in metric_keys:
                metric_keys.append(k)
    buf = io.StringIO()
    writer = csv.writer(buf, lineterminator="\n")
    writer.writerow(list(_CSV_FIELDS) + metric_keys)
    for row in rows:
        writer.writerow([row.get(f, "") for f in _CSV_FIELDS]
                        + [row["summary"].get(k, "") for k in metric_keys])
    with open(path, "w") as f:
        f.write(buf.getvalue())
    return path


def summary_table(rows: Sequence[Dict], title: str) -> str:
    """Paper-style fixed-width table over summary rows."""
    lines = [title,
             f"{'policy':<10} {'load':>5} {'seed':>4} {'makespan':>10} "
             f"{'avg JCT':>10} {'JCT lg':>9} {'JCT sm':>9} {'queue':>9} "
             f"{'q lg':>8} {'q sm':>8}"]
    for row in rows:
        s = row["summary"]
        lines.append(
            f"{row['policy']:<10} {row['load_scale']:>5.2f} "
            f"{row['seed']:>4d} {s['makespan']:>10.1f} "
            f"{s['avg_jct']:>10.1f} {s['avg_jct_large']:>9.1f} "
            f"{s['avg_jct_small']:>9.1f} {s['avg_queue']:>9.1f} "
            f"{s['avg_queue_large']:>8.1f} {s['avg_queue_small']:>8.1f}")
    return "\n".join(lines)
