"""Job and cluster state for the scheduling model (Section IV)."""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from .perf_model import PerfParams, t_iter_at_workers

try:   # the flat-array fast paths want numpy; plain-python fallbacks stay
    import numpy as _np
except ModuleNotFoundError:   # pragma: no cover - numpy-less env
    _np = None


class JobState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    SUSPENDED = "suspended"   # only preemptive baselines use this
    FINISHED = "finished"


@dataclass
class Job:
    """One DDL training job J_k (Table I notation in comments)."""

    jid: int
    model: str                  # DL task name (indexes the xi table)
    arrival: float              # a_k
    gpus: int                   # G_k
    iters: float                # I_k
    batch: int                  # B_k - user-requested per-GPU batch size
    perf: PerfParams            # Eq. 3/4/7 coefficients at G_k workers

    # --- mutable scheduling state -------------------------------------
    state: JobState = JobState.PENDING
    placement: FrozenSet[int] = frozenset()     # GPU ids
    sub_batch: int = 0          # chosen per-GPU sub-batch (Algorithm 2)
    accum_steps: int = 1        # s = batch / sub_batch
    start_time: Optional[float] = None
    finish_time: Optional[float] = None
    first_start_time: Optional[float] = None
    iters_done: float = 0.0
    last_progress_at: float = 0.0
    current_rate: float = 0.0   # iterations / second right now
    preemptions: int = 0
    failures: int = 0           # injected faults that killed an attempt
    lost_iters: float = 0.0     # work rolled back to the last checkpoint
    attained_service: float = 0.0   # gpus * seconds (Tiresias)
    alloc_gpus: Optional[int] = None  # elastic allocation (Pollux-like only)
    waiting_time: float = 0.0       # total time not holding GPUs (queue + preempted)
    # memos: solo_t_iter keyed by sub_batch, t_iter keyed by the candidate
    # sub-batch / accumulation count (scheduler sort keys and Algorithm-2
    # sub-batch sweeps hit these millions of times on large traces), the
    # solo-fit sub-batch per capacity, and the Algorithm-2 candidate
    # arrays built lazily by :mod:`repro.core.pair_batch`
    _t_iter_memo: Optional[tuple] = field(
        default=None, repr=False, compare=False)
    _t_iter_by_b: Dict[int, float] = field(
        default_factory=dict, repr=False, compare=False)
    _ert_memo: Optional[tuple] = field(
        default=None, repr=False, compare=False)
    _solo_sub_memo: Dict[float, Optional[int]] = field(
        default_factory=dict, repr=False, compare=False)
    _pair_table: Optional[tuple] = field(
        default=None, repr=False, compare=False)

    def __post_init__(self) -> None:
        if self.sub_batch == 0:
            self.sub_batch = self.batch

    # ------------------------------------------------------------------ #
    @property
    def solo_t_iter(self) -> float:
        memo = self._t_iter_memo
        if memo is not None and memo[0] == self.sub_batch:
            return memo[1]
        val = self.perf.t_iter_sub(self.batch, self.sub_batch)
        self._t_iter_memo = (self.sub_batch, val)
        return val

    def base_t_iter(self) -> float:
        """Iteration time in *user iterations* given the current elastic
        allocation (equals ``solo_t_iter`` unless a Pollux-like scheduler
        resized the job). Weak scaling: per-GPU batch fixed, progress
        normalized so that n workers advance n/G_k user iterations per
        physical iteration (same total samples => same convergence)."""
        n = self.alloc_gpus or self.gpus
        if n == self.gpus:
            return self.solo_t_iter
        t_phys = t_iter_at_workers(self.perf, self.batch, self.accum_steps, n)
        return t_phys * self.gpus / n

    def t_iter_sub(self, sub_batch: int) -> float:
        """Memoized ``perf.t_iter_sub(batch, sub_batch)`` — the
        Algorithm-2 sweep re-evaluates the same handful of candidate
        sub-batches for a job on every scheduling pass."""
        val = self._t_iter_by_b.get(sub_batch)
        if val is None:
            val = self.perf.t_iter_sub(self.batch, sub_batch)
            self._t_iter_by_b[sub_batch] = val
        return val

    @property
    def remaining_iters(self) -> float:
        return max(0.0, self.iters - self.iters_done)

    @property
    def expected_remaining_time(self) -> float:
        """L_k = t_iter * remaining iterations (solo estimate, used by
        SJF). Memoized on (iters_done, sub_batch): sort keys of queued
        jobs are re-read every scheduling pass but only change when the
        job actually progresses."""
        memo = self._ert_memo
        if (memo is not None and memo[0] == self.iters_done
                and memo[1] == self.sub_batch):
            return memo[2]
        val = self.solo_t_iter * self.remaining_iters
        self._ert_memo = (self.iters_done, self.sub_batch, val)
        return val

    @property
    def service_size(self) -> float:
        """Job 'size' used for the large/small split in Tables III-IV."""
        return self.gpus

    def jct(self) -> float:
        if self.finish_time is None:
            raise RuntimeError(f"job {self.jid} not finished")
        return self.finish_time - self.arrival

    def queueing_delay(self) -> float:
        """Total time spent without GPUs (initial queueing + time spent
        re-queued after preemption) — the paper's 'queuing delay', which
        charges preemptive policies for their migrations."""
        return self.waiting_time

    def first_start_delay(self) -> float:
        if self.first_start_time is None:
            raise RuntimeError(f"job {self.jid} never started")
        return self.first_start_time - self.arrival


@dataclass
class ClusterState:
    """Servers x GPUs with <= C jobs per GPU (C=2 in the paper).

    The free set, single-occupancy set, per-server free sets, and the
    donor (job -> #single-occupancy GPUs) index are maintained as O(Δ)
    updates inside :meth:`allocate`/:meth:`release` — the sharing
    schedulers read them every pass, and the previous version-gated
    full rescans were O(n_gpus) per pass at datacenter scale. The
    sorted list views handed to schedulers are materialized lazily from
    the sets (cached per occupancy version) so callers keep the exact
    id-ordering semantics of the original scan.
    """

    n_servers: int
    gpus_per_server: int
    max_jobs_per_gpu: int = 2
    gpu_capacity_bytes: float = 16 * 2**30

    occupancy: Dict[int, List[int]] = field(default_factory=dict)  # gpu -> [jid]
    # occupancy-version caches for the sorted list views; bumped on
    # every allocate/release
    _version: int = field(default=0, repr=False, compare=False)
    _free_cache: tuple = field(default=(-1, None), repr=False, compare=False)
    _single_cache: tuple = field(default=(-1, None), repr=False, compare=False)
    # incremental indexes (maintained by allocate/release)
    _free: Set[int] = field(default_factory=set, repr=False, compare=False)
    _single: Set[int] = field(default_factory=set, repr=False, compare=False)
    _free_by_server: List[Set[int]] = field(
        default_factory=list, repr=False, compare=False)
    _single_owner: Dict[int, int] = field(
        default_factory=dict, repr=False, compare=False)   # gpu -> sole jid
    _donor_count: Dict[int, int] = field(
        default_factory=dict, repr=False, compare=False)   # jid -> #single GPUs
    # per-server free-GPU counts as a flat preallocated array (python list
    # fallback without numpy): consolidated_pick_free reads it instead of
    # re-deriving bucket sizes, and the vectorized scheduling pass
    # (repro.core.pass_batch) reads the attached FlatJobs mirror below
    _free_count: object = field(default=None, repr=False, compare=False)
    # optional repro.core.pass_batch.FlatJobs attachment: when present,
    # donor-membership transitions are pushed into its flat donor index
    _flat: object = field(default=None, repr=False, compare=False)
    # servers currently failed (DESIGN.md §16): their GPUs are out of the
    # free pool until the matching recover event
    _down_servers: Set[int] = field(
        default_factory=set, repr=False, compare=False)

    def __post_init__(self) -> None:
        for g in range(self.n_gpus):
            self.occupancy.setdefault(g, [])
        self._free_by_server = [set() for _ in range(self.n_servers)]
        self._free_count = (_np.zeros(self.n_servers, dtype=_np.int64)
                            if _np is not None else [0] * self.n_servers)
        for g in range(self.n_gpus):
            occ = self.occupancy[g]
            if not occ:
                self._free.add(g)
                sid = self.server_of(g)
                self._free_by_server[sid].add(g)
                self._free_count[sid] += 1
            elif len(occ) == 1:
                self._mark_single(g, occ[0])

    @property
    def n_gpus(self) -> int:
        return self.n_servers * self.gpus_per_server

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_single(self) -> int:
        return len(self._single)

    @property
    def version(self) -> int:
        """Occupancy version, bumped on every allocate/release — lets
        callers cache occupancy-derived views (donor batches, sorted
        GPU lists) and invalidate them on placement changes."""
        return self._version

    def server_of(self, gpu: int) -> int:
        return gpu // self.gpus_per_server

    # -- incremental index maintenance --------------------------------- #
    def _mark_single(self, gpu: int, jid: int) -> None:
        self._single.add(gpu)
        self._single_owner[gpu] = jid
        self._donor_count[jid] = count = self._donor_count.get(jid, 0) + 1
        if self._flat is not None:
            self._flat.set_donor_singles(jid, count)

    def _unmark_single(self, gpu: int) -> None:
        self._single.discard(gpu)
        jid = self._single_owner.pop(gpu)
        left = self._donor_count[jid] - 1
        if left:
            self._donor_count[jid] = left
        else:
            del self._donor_count[jid]
        if self._flat is not None:
            self._flat.set_donor_singles(jid, left)

    # ------------------------------------------------------------------ #
    def free_gpus(self) -> List[int]:
        """GPUs with no tenant, in id order. Callers must treat the
        result as read-only: it is cached until the next
        allocate/release."""
        if self._free_cache[0] != self._version:
            self._free_cache = (self._version, sorted(self._free))
        return self._free_cache[1]

    def single_occupancy_gpus(self) -> List[int]:
        """GPUs with exactly one tenant (sharing candidates), in id
        order. Read-only; cached until the next allocate/release."""
        if self._single_cache[0] != self._version:
            self._single_cache = (self._version, sorted(self._single))
        return self._single_cache[1]

    def donor_jids(self) -> Set[int]:
        """Jobs owning at least one single-occupancy GPU (the Algorithm-1
        donor candidates). Read-only live view."""
        return self._donor_count.keys()

    def jobs_on(self, gpu: int) -> List[int]:
        return list(self.occupancy[gpu])

    @staticmethod
    def _pick_from_buckets(buckets, k: int) -> List[int]:
        """Take GPUs bucket-by-bucket (id-ascending within a bucket)
        until ``k`` are picked; may return < k (caller checks)."""
        picked: List[int] = []
        for _, gpus in buckets:
            for g in sorted(gpus):
                picked.append(g)
                if len(picked) == k:
                    return picked
        return picked

    def consolidated_pick(self, candidates: List[int], k: int) -> List[int]:
        """Pick ``k`` GPUs from ``candidates`` packed onto as few servers as
        possible (the paper's 'as consolidated on the nodes as possible')."""
        by_server: Dict[int, List[int]] = {}
        for g in candidates:
            by_server.setdefault(self.server_of(g), []).append(g)
        # Prefer servers with the most candidate GPUs; stable by server id.
        order = sorted(by_server.items(), key=lambda kv: (-len(kv[1]), kv[0]))
        return self._pick_from_buckets(order, k)

    def consolidated_pick_free(self, k: int) -> List[int]:
        """``consolidated_pick(free_gpus(), k)`` off the per-server free
        index. With numpy the common case — the request fits on the
        single most-free server — is one ``argmax`` over the flat
        free-count array; the multi-server spill sorts server ids by
        ``(-count, sid)`` with one C-level ``lexsort``. Both reproduce
        the original bucket order exactly."""
        fbs = self._free_by_server
        cnt = self._free_count
        if _np is not None:
            # first max == smallest server id among ties, the bucket head
            m = int(cnt.argmax())
            if cnt[m] >= k > 0:
                if k == 1:
                    return [min(fbs[m])]
                return sorted(fbs[m])[:k]
            order = _np.lexsort((_np.arange(self.n_servers), -cnt))
            buckets = ((int(sid), fbs[sid]) for sid in order if cnt[sid])
            return self._pick_from_buckets(buckets, k)
        order = sorted(
            ((sid, gpus) for sid, gpus in enumerate(fbs) if gpus),
            key=lambda kv: (-len(kv[1]), kv[0]))
        return self._pick_from_buckets(order, k)

    def smallest_free(self, k: int) -> List[int]:
        """The ``k`` smallest free GPU ids — ``free_gpus()[:k]`` without
        materializing (and sorting) the whole free list; the sharing
        placement only ever needs a few fill GPUs."""
        free = self._free
        if k <= 0:
            return []
        if k >= len(free):
            return sorted(free)
        if _np is not None and len(free) > 64:
            arr = _np.fromiter(free, dtype=_np.int64, count=len(free))
            head = _np.partition(arr, k - 1)[:k]
            head.sort()
            return head.tolist()
        return sorted(free)[:k]

    def allocate(self, jid: int, gpus: FrozenSet[int]) -> None:
        # the single-occupancy transitions inline _mark_single /
        # _unmark_single: allocate/release run once per placement at
        # datacenter scale and the call overhead dominates
        occupancy = self.occupancy
        free = self._free
        fbs = self._free_by_server
        fc = self._free_count
        gps = self.gpus_per_server
        max_jobs = self.max_jobs_per_gpu
        single = self._single
        owner = self._single_owner
        dcount = self._donor_count
        flat = self._flat
        for g in gpus:
            occ = occupancy[g]
            n = len(occ)
            if n >= max_jobs:
                raise RuntimeError(f"GPU {g} already holds {occ}")
            occ.append(jid)
            if n == 0:
                free.discard(g)
                sid = g // gps
                fbs[sid].discard(g)
                fc[sid] -= 1
                single.add(g)
                owner[g] = jid
                dcount[jid] = count = dcount.get(jid, 0) + 1
                if flat is not None:
                    flat.set_donor_singles(jid, count)
            elif n == 1:
                single.discard(g)
                prev = owner.pop(g)
                left = dcount[prev] - 1
                if left:
                    dcount[prev] = left
                else:
                    del dcount[prev]
                if flat is not None:
                    flat.set_donor_singles(prev, left)
        self._version += 1

    def release(self, jid: int, gpus: FrozenSet[int]) -> None:
        occupancy = self.occupancy
        free = self._free
        fbs = self._free_by_server
        fc = self._free_count
        gps = self.gpus_per_server
        single = self._single
        owner = self._single_owner
        dcount = self._donor_count
        flat = self._flat
        for g in gpus:
            occ = occupancy[g]
            if jid not in occ:
                raise RuntimeError(f"GPU {g} does not hold job {jid}")
            occ.remove(jid)
            n = len(occ)
            if n == 0:
                single.discard(g)
                prev = owner.pop(g)
                left = dcount[prev] - 1
                if left:
                    dcount[prev] = left
                else:
                    del dcount[prev]
                if flat is not None:
                    flat.set_donor_singles(prev, left)
                free.add(g)
                sid = g // gps
                fbs[sid].add(g)
                fc[sid] += 1
            elif n == 1:
                # the surviving tenant becomes the sole owner
                surv = occ[0]
                single.add(g)
                owner[g] = surv
                dcount[surv] = count = dcount.get(surv, 0) + 1
                if flat is not None:
                    flat.set_donor_singles(surv, count)
        self._version += 1

    # -- failure-aware availability (DESIGN.md §16) --------------------- #
    @property
    def down_servers(self) -> Set[int]:
        """Servers currently failed. Read-only live view."""
        return self._down_servers

    def server_gpus(self, sid: int) -> range:
        lo = sid * self.gpus_per_server
        return range(lo, lo + self.gpus_per_server)

    def set_server_down(self, sid: int) -> None:
        """Remove a (fully vacated) server's GPUs from the allocatable
        pool. Callers must have released every tenant first — the
        engine fails resident jobs before downing the server."""
        if sid in self._down_servers:
            raise RuntimeError(f"server {sid} already down")
        for g in self.server_gpus(sid):
            if self.occupancy[g]:
                raise RuntimeError(
                    f"cannot down server {sid}: GPU {g} still holds "
                    f"{self.occupancy[g]}")
            self._free.discard(g)
        self._free_by_server[sid].clear()
        self._free_count[sid] = 0
        self._down_servers.add(sid)
        self._version += 1

    def set_server_up(self, sid: int) -> None:
        """Return a recovered server's GPUs to the free pool."""
        if sid not in self._down_servers:
            raise RuntimeError(f"server {sid} is not down")
        self._down_servers.discard(sid)
        fbs = self._free_by_server[sid]
        for g in self.server_gpus(sid):
            self._free.add(g)
            fbs.add(g)
        self._free_count[sid] = self.gpus_per_server
        self._version += 1

    def co_runners(self, job: Job) -> Set[int]:
        others: Set[int] = set()
        for g in job.placement:
            for j in self.occupancy[g]:
                if j != job.jid:
                    others.add(j)
        return others
