"""Performance model of the paper (Eqs. 3, 4, 7, 14).

t_comp(B)    = alpha_comp + beta_comp * B                      (Eq. 3)
t_comm       = alpha_comm + beta_comm * M                      (Eq. 4)
t_iter(B, s) = (s-1) * t_comp(B/s)
               + (t_comp(B/s)**delta + t_comm**delta)**(1/delta)   (Eq. 7)
throughput   = B / t_iter                                      (Eq. 14)

All times are seconds, batch sizes are per-GPU samples, message sizes are
bytes. ``delta`` is the compute/communication overlap degree from Pollux
(delta=1: perfect serialization, larger delta -> more overlap).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Sequence


@dataclass(frozen=True)
class HardwareSpec:
    """Per-device hardware constants (defaults: TPU v5e)."""

    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bytes_per_s: float = 819e9  # HBM bandwidth
    link_bytes_per_s: float = 50e9  # per-link ICI bandwidth
    mem_capacity: float = 16 * 2**30  # HBM capacity in bytes
    alpha_comm: float = 15e-6       # per-collective latency (s)
    mfu: float = 0.4                # assumed achievable model-flops util

GPU_2080TI = HardwareSpec(
    peak_flops=13.4e12,            # fp32-ish effective training rate
    hbm_bytes_per_s=616e9,
    link_bytes_per_s=1.25e9,       # 10 Gbps node NIC
    mem_capacity=11 * 2**30,
    alpha_comm=50e-6,
    mfu=0.33,
)
TPU_V5E = HardwareSpec()


@dataclass(frozen=True)
class PerfParams:
    """Fitted / derived coefficients of Eqs. 3-4-7 for one (job, #GPU) setting.

    ``mem_base``/``mem_per_sample`` form the paper's memory-feasibility
    constraint mem(b) = mem_base + mem_per_sample*b <= capacity, which is
    what gradient accumulation relaxes.
    """

    alpha_comp: float
    beta_comp: float
    alpha_comm: float
    beta_comm: float
    msg_bytes: float              # gradient message size M
    delta: float = 2.0
    mem_base: float = 0.0         # bytes: params + optimizer + framework
    mem_per_sample: float = 0.0   # bytes per sample of activation footprint
    param_bytes: float = 0.0      # raw gradient size (for elastic rescaling)
    n_workers: int = 1            # worker count these params were derived at

    # ------------------------------------------------------------------ #
    def t_comp(self, batch: float) -> float:
        return self.alpha_comp + self.beta_comp * batch

    def t_comm(self) -> float:
        return self.alpha_comm + self.beta_comm * self.msg_bytes

    def t_iter(self, batch: float, accum_steps: int = 1) -> float:
        """Eq. 7 — iteration time with ``accum_steps`` gradient-accumulation
        sub-steps at sub-batch ``batch/accum_steps``."""
        if accum_steps < 1:
            raise ValueError(f"accum_steps must be >= 1, got {accum_steps}")
        sub = batch / accum_steps
        tc = self.t_comp(sub)
        tn = self.t_comm()
        overlap_tail = (tc ** self.delta + tn ** self.delta) ** (1.0 / self.delta)
        return (accum_steps - 1) * tc + overlap_tail

    def t_iter_sub(self, batch: float, sub_batch: float) -> float:
        """Eq. 7 at an explicit per-GPU sub-batch ``sub_batch``. When
        ``sub_batch`` does not divide ``batch`` the final micro-batch
        absorbs the remainder (``batch - (s-1)*sub_batch`` samples), so
        the *effective* batch — and hence convergence — is preserved for
        every candidate, not just exact divisors. For divisors this is
        identical to ``t_iter(batch, batch // sub_batch)``."""
        if sub_batch <= 0:
            raise ValueError(f"sub_batch must be positive, got {sub_batch}")
        s = max(1, math.ceil(batch / sub_batch))
        last = batch - (s - 1) * sub_batch
        tc = self.t_comp(sub_batch)
        tn = self.t_comm()
        tail = (self.t_comp(last) ** self.delta
                + tn ** self.delta) ** (1.0 / self.delta)
        return (s - 1) * tc + tail

    def throughput(self, batch: float, accum_steps: int = 1) -> float:
        return batch / self.t_iter(batch, accum_steps)

    def mem_bytes(self, sub_batch: float) -> float:
        return self.mem_base + self.mem_per_sample * sub_batch

    def fits(self, sub_batch: float, capacity: float,
             other_mem: float = 0.0) -> bool:
        return self.mem_bytes(sub_batch) + other_mem <= capacity


# ---------------------------------------------------------------------- #
# Calibration helpers
# ---------------------------------------------------------------------- #
def ring_allreduce_bytes(param_bytes: float, n_workers: int) -> float:
    """Per-worker bytes moved by a ring all-reduce of ``param_bytes``."""
    if n_workers <= 1:
        return 0.0
    return 2.0 * param_bytes * (n_workers - 1) / n_workers


def t_iter_at_workers(p: PerfParams, batch: float, accum_steps: int,
                      n_workers: int) -> float:
    """Physical iteration time of Eq. 7 re-evaluated at ``n_workers``
    ring-all-reduce participants (latency term grows with log2(n), the
    bandwidth term with the ring payload). The single elastic-rescaling
    formula shared by ``Job.base_t_iter`` and ``PolluxLike._rate``."""
    sub = batch / accum_steps
    tc = p.t_comp(sub)
    tn = (p.alpha_comm * max(1, math.ceil(math.log2(max(2, n_workers))))
          + p.beta_comm * ring_allreduce_bytes(p.param_bytes, n_workers))
    d = p.delta
    return (accum_steps - 1) * tc + (tc ** d + tn ** d) ** (1.0 / d)


def derive_perf_params(
    *,
    flops_per_sample: float,
    param_bytes: float,
    n_workers: int,
    hw: HardwareSpec,
    act_bytes_per_sample: float,
    opt_bytes: float,
    delta: float = 2.0,
    kernel_overhead: float = 2e-3,
) -> PerfParams:
    """Analytically derive Eq.3/4 coefficients for a model from its FLOPs
    and gradient size on hardware ``hw`` (used for the 10 assigned archs;
    the paper instead fits these from measured throughput — see
    ``fit_comp_params``)."""
    beta_comp = flops_per_sample / (hw.peak_flops * hw.mfu)
    msg = ring_allreduce_bytes(param_bytes, n_workers)
    beta_comm = 1.0 / hw.link_bytes_per_s
    return PerfParams(
        alpha_comp=kernel_overhead,
        beta_comp=beta_comp,
        alpha_comm=hw.alpha_comm * max(1, int(math.log2(max(2, n_workers)))),
        beta_comm=beta_comm,
        msg_bytes=msg,
        delta=delta,
        mem_base=param_bytes + opt_bytes,
        mem_per_sample=act_bytes_per_sample,
        param_bytes=param_bytes,
        n_workers=n_workers,
    )


def fit_comp_params(batches: Sequence[float],
                    times: Sequence[float]) -> tuple[float, float]:
    """Least-squares fit of Eq. 3: t = alpha + beta*B. Returns (alpha, beta)."""
    if len(batches) != len(times) or len(batches) < 2:
        raise ValueError("need >= 2 (batch, time) samples")
    n = len(batches)
    sx = sum(batches); sy = sum(times)
    sxx = sum(b * b for b in batches); sxy = sum(b * t for b, t in zip(batches, times))
    denom = n * sxx - sx * sx
    if denom == 0:
        raise ValueError("degenerate batch samples")
    beta = (n * sxy - sx * sy) / denom
    alpha = (sy - beta * sx) / n
    return alpha, beta


def infer_xi(t_iter_solo: float, t_iter_shared: float) -> float:
    """Interference ratio xi from solo vs shared iteration time (Eqs. 5-6)."""
    if t_iter_solo <= 0:
        raise ValueError("t_iter_solo must be positive")
    return t_iter_shared / t_iter_solo


def scaled(params: PerfParams, **overrides) -> PerfParams:
    return dataclasses.replace(params, **overrides)
