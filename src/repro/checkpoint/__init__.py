from .npz import (CheckpointError, checkpoint_crc, load_pytree, restore,
                  save, save_pytree)

__all__ = ["CheckpointError", "checkpoint_crc", "load_pytree", "restore",
           "save", "save_pytree"]
