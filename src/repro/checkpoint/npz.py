"""Minimal npz pytree checkpointing: flatten with '/'-joined key paths,
save atomically (tmp file + fsync + rename), restore into the same tree
structure. A corrupted or truncated file raises :class:`CheckpointError`
with the path and cause, never a raw ``zipfile`` traceback — the
executor's recovery path (DESIGN.md §16) decides whether to fall back to
an older checkpoint or restart from scratch.

Every checkpoint carries a CRC32 **content** checksum (``__crc32__``,
computed over the sorted keys and raw array bytes, independent of zip
metadata): silent bit-rot that still parses as a valid npz — the failure
mode fsync+rename cannot catch — surfaces as :class:`CheckpointError`
on load instead of restarting training from corrupt state. The stored
CRC doubles as a cheap cross-process state digest: the fleet master
compares agents' checkpoint CRCs against the single-host executor's to
assert bit-exact recovery (DESIGN.md §17). Files written before the
checksum existed load unchecked."""
from __future__ import annotations

import os
import tempfile
import zipfile
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CheckpointError", "checkpoint_crc", "load_pytree", "restore",
           "save", "save_pytree"]

_CRC_KEY = "__crc32__"


class CheckpointError(RuntimeError):
    """A checkpoint file is unreadable (corrupted, truncated, or not an
    npz archive). Carries ``path`` so recovery code can report which
    file is damaged."""

    def __init__(self, path: str, reason: str) -> None:
        self.path = path
        super().__init__(f"checkpoint {path!r} is unreadable: {reason}")


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(getattr(p, "idx", p)) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _content_crc(flat: Dict[str, np.ndarray]) -> int:
    """CRC32 over the flattened content in sorted-key order: each key,
    its dtype/shape, and the raw array bytes. Deterministic for equal
    content regardless of zip timestamps or member ordering."""
    crc = 0
    for key in sorted(flat):
        arr = np.ascontiguousarray(flat[key])
        for token in (key, str(arr.dtype), str(arr.shape)):
            crc = zlib.crc32(token.encode(), crc)
        crc = zlib.crc32(arr.tobytes(), crc)
    return crc & 0xFFFFFFFF


def save_pytree(path: str, tree) -> None:
    flat = _flatten(tree)
    flat[_CRC_KEY] = np.asarray(_content_crc(flat), dtype=np.uint32)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    # NOTE: np.savez appends ".npz" unless the name already ends with it
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp.npz")
    os.close(fd)
    try:
        np.savez(tmp, **flat)
        # fsync before rename: os.replace is atomic on the directory
        # entry, but a crash between write and flush could otherwise
        # publish a truncated file under the final name
        fd = os.open(tmp, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_pytree(path: str, like) -> Any:
    """Restore into the structure of ``like`` (a template pytree).

    Raises :class:`FileNotFoundError` if ``path`` does not exist and
    :class:`CheckpointError` if it exists but cannot be parsed.
    """
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    try:
        with np.load(path) as data:
            flat = {k: data[k] for k in data.files}
    except (zipfile.BadZipFile, ValueError, EOFError, OSError) as exc:
        raise CheckpointError(path, f"{type(exc).__name__}: {exc}") from exc
    stored = flat.pop(_CRC_KEY, None)
    if stored is not None:
        stored_crc = int(stored)
        computed = _content_crc(flat)
        if computed != stored_crc:
            raise CheckpointError(
                path, f"content CRC mismatch: stored {stored_crc:#010x}, "
                      f"computed {computed:#010x} (silent bit-rot)")
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_keys, leaf in leaves_like:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(getattr(p, "idx", p)) for p in path_keys)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


def checkpoint_crc(path: str) -> Optional[int]:
    """The stored content CRC of a checkpoint file (``None`` for files
    written before the checksum existed). Cheap — reads one tiny npz
    member — so the fleet layer uses it as the per-job state digest when
    comparing cross-process runs against the single-host executor."""
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    try:
        with np.load(path) as data:
            if _CRC_KEY not in data.files:
                return None
            return int(data[_CRC_KEY])
    except (zipfile.BadZipFile, ValueError, EOFError, OSError) as exc:
        raise CheckpointError(path, f"{type(exc).__name__}: {exc}") from exc


def save(path: str, *, params, opt_state=None, step: int = 0,
         extra: Optional[Dict] = None) -> None:
    tree = {"params": params, "step": jnp.asarray(step)}
    if opt_state is not None:
        tree["opt"] = opt_state
    if extra:
        tree["extra"] = extra
    save_pytree(path, tree)


def restore(path: str, *, params_like, opt_like=None) -> Tuple:
    like = {"params": params_like, "step": jnp.zeros((), jnp.int32)}
    if opt_like is not None:
        like["opt"] = opt_like
    tree = load_pytree(path, like)
    return (tree["params"], tree.get("opt"), int(tree["step"]))
