"""Loss + the jit-able train step used by smoke tests, the quickstart
example, the co-schedule testbed and the dry-run."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import forward

from .grad_accum import accumulate_gradients
from .optimizer import OptState, adamw_update


@dataclass(frozen=True)
class TrainConfig:
    accum_steps: int = 1            # s — gradient-accumulation sub-steps
    lr: float = 3e-4
    weight_decay: float = 0.1
    aux_loss_weight: float = 0.01   # MoE load-balance
    remat: bool = True
    use_kernels: bool = False
    accum_dtype: str = "float32"
    schedule: Optional[Callable] = None   # overrides lr when set
    # §Perf A2: re-shard gradients to the parameter sharding before the
    # optimizer (forces reduce-scatter instead of a full-size all-reduce)
    # and optionally reduce them in bf16.
    reshard_grads: bool = False
    grad_reduce_dtype: Optional[str] = None


def loss_fn(cfg: ArchConfig, params, batch: Dict[str, jnp.ndarray], *,
            aux_loss_weight: float = 0.01, remat: bool = True,
            use_kernels: bool = False) -> Tuple[jnp.ndarray, Dict]:
    """Mean next-token CE. An optional ``sample_mask`` (B,) entry marks
    padded rows of a ragged final micro-batch (grad_accum.py): masked
    samples contribute nothing to the CE term and the mean runs over
    valid samples. The MoE aux loss is a batch statistic (DESIGN.md §8)
    and is NOT masked — padded rows do pass through the router, so MoE
    accumulation equivalence holds only with aux_loss_weight=0."""
    logits, aux = forward(cfg, params, batch, remat=remat,
                          use_kernels=use_kernels)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("sample_mask")
    if mask is None:
        ce = -jnp.mean(ll)
    else:
        ce = -jnp.sum(ll * mask[:, None]) / (
            jnp.maximum(jnp.sum(mask), 1.0) * ll.shape[1])
    loss = ce + aux_loss_weight * aux
    return loss, {"ce": ce, "aux": aux}


def make_train_step(cfg: ArchConfig, tc: TrainConfig = TrainConfig()):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). Gradient accumulation is a ``lax.scan`` over micro-batches
    (the paper's mechanism; memory scales with batch/accum_steps)."""

    def lg(params, micro_batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, micro_batch,
                              aux_loss_weight=tc.aux_loss_weight,
                              remat=tc.remat, use_kernels=tc.use_kernels),
            has_aux=True)(params)
        return loss, grads

    def train_step(params, opt_state: OptState, batch):
        loss, grads = accumulate_gradients(
            lg, params, batch, tc.accum_steps,
            accum_dtype=jnp.dtype(tc.accum_dtype))
        if tc.grad_reduce_dtype is not None:
            gdt = jnp.dtype(tc.grad_reduce_dtype)
            grads = jax.tree.map(lambda g: g.astype(gdt), grads)
        if tc.reshard_grads:
            from repro.sharding.hooks import constrain_params_tree
            grads = constrain_params_tree(grads)
        lr = tc.schedule if tc.schedule is not None else tc.lr
        params, opt_state = adamw_update(
            grads, opt_state, params, lr=lr, weight_decay=tc.weight_decay)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_jit_train_step(cfg: ArchConfig, tc: TrainConfig = TrainConfig(), *,
                        donate: bool = True):
    """``make_train_step`` jitted with params/opt-state DONATED: the
    gradient-accumulation buffers and the AdamW moment update reuse the
    input HBM in place instead of allocating a second copy — halving the
    peak optimizer-state footprint on TPU. Callers must re-bind
    (params, opt_state) from the outputs every step (the donated inputs
    are invalidated); `repro.core.coschedule` and `repro.launch.train`
    thread state that way."""
    step = make_train_step(cfg, tc)
    return jax.jit(step, donate_argnums=(0, 1) if donate else ())
