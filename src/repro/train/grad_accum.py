"""Gradient accumulation — the paper's enabling mechanism (Section IV-A.4).

``accumulate_gradients`` splits the per-step batch into micro-batches
along the batch axis and scans over them, summing gradients. From the
optimizer's perspective this is *exactly* one step at the full batch size
(Eq. 1 is linear in the per-sample gradients), which is the paper's "no
accuracy change" claim; ``tests/test_grad_accum.py`` proves the
equivalence numerically.

Non-divisor splits are supported with the same semantics the simulator
prices (``candidate_sub_batches`` / ``PerfParams.t_iter_sub``): the
micro-batch size is ``b = ceil(B / accum_steps)``, the scan runs
``s = ceil(B / b)`` steps, and the final micro-batch absorbs the
remainder — padded to ``b`` rows and masked via a per-sample
``sample_mask`` entry so padded rows contribute nothing to the DATA loss
or its gradients. Each micro-batch's mean is re-weighted by its
valid-sample count, so the result is still the exact full-batch mean of
the CE term. Caveat (same family as DESIGN.md §8): the MoE load-balance
aux loss is a batch statistic — it is not linear in the batch split even
for divisible batches, and padded rows additionally pass through the
router — so exactness claims are about the data loss (aux_loss_weight=0
for strict MoE equivalence, as ``tests/test_grad_accum.py`` pins).

The accumulation buffer dtype is configurable: bf16 accumulation halves
the working set for the >=100B configs (DESIGN.md §7).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def _leading_dim(batch) -> int:
    dims = {leaf.shape[0] for leaf in jax.tree.leaves(batch)}
    assert len(dims) == 1, f"inconsistent batch leading dims: {dims}"
    return dims.pop()


def accumulate_gradients(
    loss_and_grad: Callable,           # (params, micro_batch) -> (loss, grads)
    params,
    batch,
    accum_steps: int,
    *,
    accum_dtype=jnp.float32,
) -> Tuple[jnp.ndarray, Any]:
    """Returns (mean loss, mean grads) over the micro-batches of ``batch``.

    ``batch`` is a pytree whose leaves have a common leading dim B;
    micro-batches are ``leaf[i*b:(i+1)*b]`` with ``b = ceil(B /
    accum_steps)``. When ``b`` does not divide B the final micro-batch is
    padded and a ``sample_mask`` key is added (``batch`` must then be a
    dict and ``loss_and_grad`` mask-aware, as ``loss_fn`` is).
    """
    if accum_steps <= 1:
        return loss_and_grad(params, batch)

    # ``sample_mask`` is reserved for the ragged-path injection below: a
    # caller-supplied mask would be clobbered on the ragged path and
    # mis-weighted by the uniform 1/steps average on the divisible one.
    assert not (isinstance(batch, dict) and "sample_mask" in batch), (
        "sample_mask is injected by accumulate_gradients; pre-masked "
        "batches are only supported with accum_steps=1")

    big = _leading_dim(batch)
    sub = math.ceil(big / accum_steps)
    steps = math.ceil(big / sub)

    if big % sub == 0:
        # uniform micro-batches: the historical exact path
        def micro(leaf):
            return leaf.reshape(steps, sub, *leaf.shape[1:])

        micro_batches = jax.tree.map(micro, batch)

        def step(carry, mb):
            loss_acc, grads_acc = carry
            loss, grads = loss_and_grad(params, mb)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(accum_dtype), grads_acc, grads)
            return (loss_acc + loss.astype(jnp.float32), grads_acc), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, accum_dtype), params)
        (loss_sum, grads_sum), _ = jax.lax.scan(
            step, (jnp.zeros((), jnp.float32), zeros), micro_batches)
        inv = 1.0 / steps
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grads_sum)

    # ragged final micro-batch: pad + mask, weight each micro by its
    # valid-sample share so the sum is the exact full-batch mean
    assert isinstance(batch, dict), (
        "non-divisor grad accumulation needs a dict batch (a sample_mask "
        f"entry is injected); got {type(batch).__name__}")
    last = big - (steps - 1) * sub
    padded = steps * sub

    def micro(leaf):
        pad = [(0, padded - big)] + [(0, 0)] * (leaf.ndim - 1)
        return jnp.pad(leaf, pad).reshape(steps, sub, *leaf.shape[1:])

    micro_batches = jax.tree.map(micro, batch)
    micro_batches["sample_mask"] = (
        jnp.arange(padded, dtype=jnp.float32).reshape(steps, sub) < big
    ).astype(jnp.float32)
    counts = jnp.full((steps,), float(sub), jnp.float32).at[-1].set(last)
    weights = counts / big                       # sums to 1

    def step(carry, inp):
        loss_acc, grads_acc = carry
        mb, wgt = inp
        loss, grads = loss_and_grad(params, mb)
        # weight in f32, then cast: keeps the n_i/B factor exact and the
        # scan carry dtype stable when accum_dtype is bf16
        grads_acc = jax.tree.map(
            lambda a, g: a + (wgt * g).astype(accum_dtype),
            grads_acc, grads)
        return (loss_acc + wgt * loss.astype(jnp.float32), grads_acc), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
    (loss_sum, grads_sum), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), zeros), (micro_batches, weights))
    return loss_sum, grads_sum
