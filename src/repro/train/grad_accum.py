"""Gradient accumulation — the paper's enabling mechanism (Section IV-A.4).

``accumulate_gradients`` splits the per-step batch into ``s`` micro-batches
along the batch axis and scans over them, summing gradients. From the
optimizer's perspective this is *exactly* one step at the full batch size
(Eq. 1 is linear in the per-sample gradients), which is the paper's "no
accuracy change" claim; ``tests/test_grad_accum.py`` proves the
equivalence numerically.

The accumulation buffer dtype is configurable: bf16 accumulation halves
the working set for the >=100B configs (DESIGN.md §7).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def accumulate_gradients(
    loss_and_grad: Callable,           # (params, micro_batch) -> (loss, grads)
    params,
    batch,
    accum_steps: int,
    *,
    accum_dtype=jnp.float32,
) -> Tuple[jnp.ndarray, Any]:
    """Returns (mean loss, mean grads) over ``accum_steps`` micro-batches.

    ``batch`` is a pytree whose leaves have leading dim B divisible by
    ``accum_steps``; micro-batch i is ``leaf[i*b:(i+1)*b]``.
    """
    if accum_steps <= 1:
        return loss_and_grad(params, batch)

    def micro(leaf):
        b = leaf.shape[0]
        assert b % accum_steps == 0, (b, accum_steps)
        return leaf.reshape(accum_steps, b // accum_steps, *leaf.shape[1:])

    micro_batches = jax.tree.map(micro, batch)

    def step(carry, mb):
        loss_acc, grads_acc = carry
        loss, grads = loss_and_grad(params, mb)
        grads_acc = jax.tree.map(
            lambda a, g: a + g.astype(accum_dtype), grads_acc, grads)
        return (loss_acc + loss.astype(jnp.float32), grads_acc), None

    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, accum_dtype), params)
    (loss_sum, grads_sum), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), zeros), micro_batches)
    inv = 1.0 / accum_steps
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, grads_sum)
