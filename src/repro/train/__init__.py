"""Training substrate: gradient accumulation (the paper's enabling
mechanism), AdamW, LR schedules, loss and the jit-able train step."""
from .grad_accum import accumulate_gradients
from .optimizer import (OptState, adamw_init, adamw_update, wsd_schedule,
                        cosine_schedule)
from .train_step import (TrainConfig, loss_fn, make_jit_train_step,
                         make_train_step)

__all__ = ["OptState", "TrainConfig", "accumulate_gradients", "adamw_init",
           "adamw_update", "cosine_schedule", "loss_fn",
           "make_jit_train_step", "make_train_step", "wsd_schedule"]
