"""AdamW with optional bf16 moments (the >=100B configs need them to fit
16 GiB/chip; DESIGN.md §7) and the WSD schedule MiniCPM trains with."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params, moment_dtype=jnp.float32) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(grads, opt: OptState, params, *, lr,
                 b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1):
    """Returns (new_params, new_opt). ``lr`` may be a scalar or a
    schedule(step) callable."""
    step = opt.step + 1
    if callable(lr):
        lr_t = lr(step)
    else:
        lr_t = lr
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * \
            p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr_t * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, opt.m, opt.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step=step, m=new_m, v=new_v)


# ---------------------------------------------------------------------- #
def wsd_schedule(*, peak_lr: float, warmup_steps: int, stable_steps: int,
                 decay_steps: int, floor: float = 0.0) -> Callable:
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395): linear warmup,
    long constant plateau, short exponential-ish (linear here) decay."""
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        decay_frac = (step - warmup_steps - stable_steps) / max(decay_steps, 1)
        decay = peak_lr * jnp.maximum(1.0 - decay_frac, 0.0) + floor
        return jnp.where(step < warmup_steps, warm,
                         jnp.where(step < warmup_steps + stable_steps,
                                   peak_lr, decay))
    return lr


def cosine_schedule(*, peak_lr: float, warmup_steps: int,
                    total_steps: int, floor_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr
